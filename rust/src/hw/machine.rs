//! The **Matrix Machine**: global controller + ring FIFO + processor
//! groups, executing assembled vector programs on one FPGA (paper §4,
//! Fig 4).
//!
//! Two execution paths share the same numerics:
//!
//! * [`MatrixMachine::run`] — the fast path: functional execution via
//!   [`super::fast::FastSim`] with cycle charging from the structural
//!   per-batch model ([`crate::perf::group`]) + the DDR/DMA model + ring
//!   distribution overhead. Groups execute batches in parallel; a wave's
//!   cost is the per-group batch schedule's makespan.
//! * [`MatrixMachine::run_verified`] — the checked path: every wave is
//!   additionally lowered to microcode ([`crate::assembler::microcode_gen`])
//!   and executed on the structural [`MvmGroup`]/[`ActproGroup`]
//!   interpreters; outputs are asserted bit-identical to the fast path.
//!   Used by integration tests and available from the CLI (`--verify`).
//!
//! Ring overhead model: each batch's microcode + operands are distributed
//! over the circular FIFO (Fig 4); we charge the worst-case hop count
//! (`groups` stations) once per batch wavefront, which is what the paper's
//! "the FIFO reduces the propagation delay" buys relative to a flat bus.

use super::fast::FastSim;
use super::fpga::FpgaDevice;
use super::group::{ActproGroup, GroupIo, MvmGroup};
use super::Cycle;
use crate::assembler::microcode_gen;
use crate::assembler::program::{Program, ProgramError, Step, Wave};
use crate::isa::Opcode;
use crate::perf::group::{structural_actpro_batch_cycles, structural_mvm_batch_cycles};
use thiserror::Error;

/// Machine execution errors.
#[derive(Debug, Error)]
pub enum MachineError {
    /// Program failed validation.
    #[error("invalid program: {0}")]
    Invalid(#[from] ProgramError),
    /// A named buffer is missing.
    #[error("unknown buffer {0:?}")]
    UnknownBuffer(String),
    /// Bound data has the wrong length.
    #[error("buffer {0:?} expects {1} lanes, got {2}")]
    LengthMismatch(String, usize, usize),
    /// Structural verification diverged from the fast path.
    #[error("verification mismatch in step {0}: structural != functional")]
    VerifyMismatch(usize),
}

/// Cycle/work statistics of one program run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunStats {
    /// Total simulated cycles.
    pub cycles: Cycle,
    /// Cycles spent in DDR DMA.
    pub dma_cycles: Cycle,
    /// Cycles spent in compute batches (group makespan).
    pub compute_cycles: Cycle,
    /// Cycles spent streaming LUTs.
    pub lut_cycles: Cycle,
    /// Ring-distribution overhead cycles.
    pub ring_cycles: Cycle,
    /// Waves executed.
    pub waves: u64,
    /// Lane-operations executed (work metric).
    pub lane_ops: u64,
    /// Bytes moved over DDR.
    pub dma_bytes: u64,
}

impl RunStats {
    /// Merge another run's stats.
    pub fn add(&mut self, o: &RunStats) {
        self.cycles += o.cycles;
        self.dma_cycles += o.dma_cycles;
        self.compute_cycles += o.compute_cycles;
        self.lut_cycles += o.lut_cycles;
        self.ring_cycles += o.ring_cycles;
        self.waves += o.waves;
        self.lane_ops += o.lane_ops;
        self.dma_bytes += o.dma_bytes;
    }

    /// Wall-clock seconds on `device`.
    pub fn seconds(&self, device: &FpgaDevice) -> f64 {
        device.seconds(self.cycles)
    }

    /// Lane-ops per second on `device`.
    pub fn lane_ops_per_sec(&self, device: &FpgaDevice) -> f64 {
        self.lane_ops as f64 / self.seconds(device).max(1e-30)
    }
}

/// One simulated Matrix Machine.
#[derive(Debug, Clone)]
pub struct MatrixMachine {
    /// The board this machine is generated for.
    pub device: FpgaDevice,
    sim: FastSim,
    program_name: String,
    /// LUT → ACTPRO-group residency (perf pass, EXPERIMENTS.md §Perf):
    /// when the program's distinct tables fit the board's ACTPRO groups,
    /// the global controller partitions the groups among them at first
    /// load and never re-streams a table. `lut_groups[lut]` = groups
    /// dedicated to that table; `lut_resident[lut]` = already streamed.
    lut_groups: Vec<u64>,
    lut_resident: Vec<bool>,
}

impl MatrixMachine {
    /// Build a machine for `device` loaded with `program` (validates it).
    pub fn new(device: FpgaDevice, program: &Program) -> Result<MatrixMachine, MachineError> {
        program.check()?;
        let n_luts = program.luts.len();
        let groups = device.actpro_groups.max(1) as u64;
        let lut_groups = if n_luts == 0 {
            Vec::new()
        } else if n_luts as u64 <= groups {
            // Static partition: spread groups over tables.
            let base = groups / n_luts as u64;
            let extra = groups % n_luts as u64;
            (0..n_luts as u64).map(|i| base + u64::from(i < extra)).collect()
        } else {
            // More tables than groups: every LoadLut re-streams to all
            // groups (pre-optimisation behaviour).
            vec![groups; n_luts]
        };
        Ok(MatrixMachine {
            device,
            sim: FastSim::new(program),
            program_name: program.name.clone(),
            lut_groups,
            lut_resident: vec![false; n_luts],
        })
    }

    /// Are the program's tables statically resident (no re-streaming)?
    fn luts_static(&self) -> bool {
        (self.lut_resident.len() as u64) <= self.device.actpro_groups.max(1) as u64
    }

    /// Program name this machine was built for.
    pub fn program_name(&self) -> &str {
        &self.program_name
    }

    /// Bind data to a named buffer.
    pub fn bind(
        &mut self,
        program: &Program,
        name: &str,
        data: &[i16],
    ) -> Result<(), MachineError> {
        let id = program
            .buffer_named(name)
            .ok_or_else(|| MachineError::UnknownBuffer(name.to_string()))?;
        let want = program.buffers[id].len();
        if want != data.len() {
            return Err(MachineError::LengthMismatch(name.to_string(), want, data.len()));
        }
        self.sim.set_buffer(id, data);
        Ok(())
    }

    /// Read a named buffer after a run.
    pub fn read(&self, program: &Program, name: &str) -> Result<Vec<i16>, MachineError> {
        let id = program
            .buffer_named(name)
            .ok_or_else(|| MachineError::UnknownBuffer(name.to_string()))?;
        Ok(self.sim.buffer(id).to_vec())
    }

    /// Read a buffer by id.
    pub fn read_id(&self, id: usize) -> &[i16] {
        self.sim.buffer(id)
    }

    /// Cycle cost of one wave on this machine's group allocation.
    fn wave_cycles(&self, wave: &Wave) -> (Cycle, Cycle) {
        let (groups, batch_cost): (u64, Box<dyn Fn(usize) -> u64>) =
            if wave.op == Opcode::ActivationFunction {
                // Under static residency an ACT wave runs only on the
                // groups holding its table.
                let g = if self.luts_static() {
                    self.lut_groups[wave.lut.expect("checked: ACT wave has LUT")]
                } else {
                    self.device.actpro_groups.max(1) as u64
                };
                (
                    g.max(1),
                    Box::new(move |procs| structural_actpro_batch_cycles(wave.vec_len, procs)),
                )
            } else {
                let op = wave.op;
                let len = wave.vec_len;
                (
                    self.device.mvm_groups.max(1) as u64,
                    Box::new(move |procs| structural_mvm_batch_cycles(op, len, procs)),
                )
            };
        let lanes = wave.lanes.len() as u64;
        let procs_total = groups * super::PROCS_PER_GROUP as u64;
        // Full wavefronts of `procs_total` lanes, then a remainder.
        let full_waves = lanes / procs_total;
        let rem_lanes = lanes % procs_total;
        let mut compute = full_waves * batch_cost(super::PROCS_PER_GROUP);
        if rem_lanes > 0 {
            // The remainder occupies ceil(rem/groups) procs in the slowest
            // group.
            let procs = (rem_lanes as usize).div_ceil(groups as usize).min(super::PROCS_PER_GROUP);
            compute += batch_cost(procs);
        }
        // Ring overhead: one worst-case traversal per batch wavefront
        // (stations = groups + global controller).
        let wavefronts = full_waves + (rem_lanes > 0) as u64;
        let ring = wavefronts * (groups + 1);
        (compute, ring)
    }

    /// Execute the program on the fast path.
    pub fn run(&mut self, program: &Program) -> Result<RunStats, MachineError> {
        self.run_inner(program, false)
    }

    /// Execute with per-wave structural verification (slow; tests/CLI).
    pub fn run_verified(&mut self, program: &Program) -> Result<RunStats, MachineError> {
        self.run_inner(program, true)
    }

    fn run_inner(&mut self, program: &Program, verify: bool) -> Result<RunStats, MachineError> {
        let mut st = RunStats::default();
        for (si, step) in program.steps.iter().enumerate() {
            match step {
                Step::LoadDram(b) | Step::StoreDram(b) => {
                    let bytes = program.buffers[*b].len() as u64 * 2;
                    let c = self.device.dma_cycles(bytes);
                    st.dma_cycles += c;
                    st.cycles += c;
                    st.dma_bytes += bytes;
                }
                Step::LoadLut(l) => {
                    // Streamed in parallel to the groups that will hold the
                    // table; within a group the 4 procs share the input
                    // port pair. Under static residency the stream happens
                    // once per machine lifetime (perf pass, §Perf).
                    if !self.luts_static() || !self.lut_resident[*l] {
                        let table_len = program.luts[*l].table().len() as u64;
                        let c = (table_len / 2 + 1) * super::PROCS_PER_GROUP as u64;
                        st.lut_cycles += c;
                        st.cycles += c;
                        self.lut_resident[*l] = true;
                    }
                }
                Step::Wave(w) => {
                    if verify {
                        self.verify_wave(program, si, w)?;
                    }
                    self.sim.exec_wave(program, w);
                    let (compute, ring) = self.wave_cycles(w);
                    st.compute_cycles += compute;
                    st.ring_cycles += ring;
                    st.cycles += compute + ring;
                    st.waves += 1;
                    st.lane_ops += (w.lanes.len() * w.vec_len) as u64;
                }
            }
        }
        Ok(st)
    }

    /// Execute one wave on the structural group interpreters and compare
    /// against what the fast path will produce.
    fn verify_wave(&self, program: &Program, si: usize, w: &Wave) -> Result<(), MachineError> {
        // Compute expected outputs functionally on a scratch copy.
        let mut scratch = self.sim.clone();
        scratch.exec_wave(program, w);

        let procs = super::PROCS_PER_GROUP;
        for chunk in w.lanes.chunks(procs) {
            let mut io = GroupIo::default();
            for lane in chunk {
                io.feed(&self.sim.gather(&lane.a));
                if w.op != Opcode::ActivationFunction && w.op != Opcode::VectorSummation {
                    if let Some(b) = &lane.b {
                        io.feed(&self.sim.gather(b));
                    }
                }
            }
            let out_per_lane: usize;
            match w.op {
                Opcode::ActivationFunction => {
                    let lut = &program.luts[w.lut.expect("checked")];
                    let words = microcode_gen::actpro_batch(w.vec_len, chunk.len())
                        .expect("checked wave dims");
                    let mut g = ActproGroup::new(lut.clone());
                    g.execute(&words, &mut io);
                    out_per_lane = w.vec_len + (w.vec_len & 1);
                }
                op => {
                    let words = microcode_gen::mvm_batch(op, w.vec_len, chunk.len())
                        .expect("checked wave dims");
                    let mut g = MvmGroup::new(program.fixed);
                    g.execute(&words, &mut io);
                    out_per_lane = match op {
                        Opcode::VectorDotProduct | Opcode::VectorSummation => 1,
                        _ => w.vec_len,
                    };
                }
            }
            for (li, lane) in chunk.iter().enumerate() {
                let got = &io.output[li * out_per_lane..li * out_per_lane + lane.out.len];
                let want = scratch.gather(&lane.out);
                if got != want.as_slice() {
                    return Err(MachineError::VerifyMismatch(si));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{BufKind, LaneOp, View};
    use crate::fixed::FixedSpec;
    use crate::nn::lut::{ActKind, ActLut, AddrMode};
    use crate::util::Rng;

    const S: FixedSpec = FixedSpec::PAPER;

    /// x (+) x → act → out, with DMA steps.
    fn small_program() -> (Program, usize, usize) {
        let mut p = Program::new("t", S);
        let x = p.buffer("x", 64, 1, BufKind::Input);
        let o = p.buffer("o", 64, 1, BufKind::Output);
        let lut = p.lut(ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7));
        p.steps.push(Step::LoadDram(x));
        p.steps.push(Step::LoadLut(lut));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 64,
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(x, 64),
                b: Some(View::all(x, 64)),
                out: View::all(o, 64),
            }],
        }));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::ActivationFunction,
            vec_len: 64,
            lut: Some(lut),
            lanes: vec![LaneOp { a: View::all(o, 64), b: None, out: View::all(o, 64) }],
        }));
        p.steps.push(Step::StoreDram(o));
        (p, x, o)
    }

    #[test]
    fn run_produces_expected_numerics_and_stats() {
        let (p, _, _) = small_program();
        let mut r = Rng::new(31);
        let xs: Vec<i16> = (0..64).map(|_| r.gen_range_i64(-3000, 3000) as i16).collect();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        m.bind(&p, "x", &xs).unwrap();
        let st = m.run(&p).unwrap();
        let lut = &p.luts[0];
        let want = lut.apply(&S.vadd(&xs, &xs));
        assert_eq!(m.read(&p, "o").unwrap(), want);
        assert_eq!(st.waves, 2);
        assert_eq!(st.lane_ops, 128);
        assert!(st.dma_cycles > 0 && st.compute_cycles > 0 && st.lut_cycles > 0);
        assert_eq!(
            st.cycles,
            st.dma_cycles + st.compute_cycles + st.lut_cycles + st.ring_cycles
        );
    }

    #[test]
    fn verified_run_matches_fast_run() {
        let (p, _, _) = small_program();
        let mut r = Rng::new(32);
        let xs: Vec<i16> = (0..64).map(|_| r.gen_range_i64(-3000, 3000) as i16).collect();
        let mut fast = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        let mut slow = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        fast.bind(&p, "x", &xs).unwrap();
        slow.bind(&p, "x", &xs).unwrap();
        let sf = fast.run(&p).unwrap();
        let sv = slow.run_verified(&p).unwrap();
        assert_eq!(fast.read(&p, "o").unwrap(), slow.read(&p, "o").unwrap());
        assert_eq!(sf.cycles, sv.cycles);
    }

    #[test]
    fn multi_lane_wave_distributes_over_groups() {
        // 128 dot products on a 16-group machine: 2 wavefronts of 64.
        let mut p = Program::new("dots", S);
        let a = p.buffer("a", 128, 32, BufKind::Input);
        let o = p.buffer("o", 128, 1, BufKind::Output);
        let lanes: Vec<LaneOp> = (0..128)
            .map(|i| LaneOp {
                a: View::contiguous(a, i * 32, 32),
                b: Some(View::contiguous(a, ((i + 1) % 128) * 32, 32)),
                out: View::contiguous(o, i, 1),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorDotProduct,
            vec_len: 32,
            lut: None,
            lanes,
        }));
        let mut r = Rng::new(33);
        let data: Vec<i16> = (0..128 * 32).map(|_| r.gen_i16()).collect();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        m.bind(&p, "a", &data).unwrap();
        let st = m.run(&p).unwrap();
        // expected: each lane dot(a[i], a[i+1])
        for i in 0..128 {
            let x = &data[i * 32..(i + 1) * 32];
            let y = &data[((i + 1) % 128) * 32..((i + 1) % 128) * 32 + 32];
            assert_eq!(m.read(&p, "o").unwrap()[i], S.dot(x, y), "lane {i}");
        }
        // 2 full wavefronts (128 lanes / 64 procs), each costing one
        // 4-proc batch.
        let batch = structural_mvm_batch_cycles(Opcode::VectorDotProduct, 32, 4);
        assert_eq!(st.compute_cycles, 2 * batch);
        assert_eq!(st.ring_cycles, 2 * 17);
    }

    #[test]
    fn errors_on_bad_bindings() {
        let (p, _, _) = small_program();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        assert!(matches!(
            m.bind(&p, "nope", &[0]),
            Err(MachineError::UnknownBuffer(_))
        ));
        assert!(matches!(
            m.bind(&p, "x", &[0; 3]),
            Err(MachineError::LengthMismatch(_, 64, 3))
        ));
    }

    #[test]
    fn invalid_program_rejected_at_construction() {
        let mut p = Program::new("bad", S);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 9, // OOB
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(x, 9),
                b: Some(View::all(x, 9)),
                out: View::all(x, 9),
            }],
        }));
        assert!(MatrixMachine::new(FpgaDevice::selected(), &p).is_err());
    }
}
