//! 8-bit address counters (paper §4.1: "The 8 bit input counter is used to
//! select the input addresses of the individual MVMs... The output counters
//! are designed to mirror the input counters").
//!
//! The counter value addresses a 512-entry column; the column-select bit
//! supplies the BRAM address MSB (and the 10th bit for full-BRAM sweeps is
//! handled by the group controller issuing two column passes).

/// A clocked 8-bit-style counter with enable and synchronous reset.
/// Width is parameterised because the ACTPRO's LUT sweep uses 9 bits.
#[derive(Debug, Clone)]
pub struct Counter {
    value: u16,
    width: u32,
}

impl Counter {
    /// New counter of `width` bits, starting at 0.
    pub fn new(width: u32) -> Counter {
        assert!(width <= 16);
        Counter { value: 0, width }
    }

    /// Paper's 8-bit counter.
    pub fn bit8() -> Counter {
        Counter::new(8)
    }

    /// Current value.
    pub fn value(&self) -> u16 {
        self.value
    }

    /// Clock edge: increment when enabled (wraps at 2^width).
    pub fn clock(&mut self, enable: bool) {
        if enable {
            self.value = (self.value + 1) & ((1 << self.width) - 1);
        }
    }

    /// Synchronous reset to zero.
    pub fn reset(&mut self) {
        self.value = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_when_enabled() {
        let mut c = Counter::bit8();
        c.clock(true);
        c.clock(true);
        c.clock(false);
        assert_eq!(c.value(), 2);
    }

    #[test]
    fn wraps_at_width() {
        let mut c = Counter::new(2);
        for _ in 0..5 {
            c.clock(true);
        }
        assert_eq!(c.value(), 1); // 5 mod 4
    }

    #[test]
    fn reset() {
        let mut c = Counter::bit8();
        c.clock(true);
        c.reset();
        assert_eq!(c.value(), 0);
    }
}
