//! Activation Processor (§4.3, Table 7, Figs 9–10).
//!
//! Structure (Fig 9): 3 × BRAM (left = input, middle = activation lookup
//! table, right = output), 2 counters, control logic. "The left BRAM is
//! connected to the dual bit shifts. Each bit shifter applies a 7 bit shift
//! to the right. After the dual bit shifts, the values are used as
//! addresses to look-up the results for the activation functions."
//!
//! Two elements flow per cycle (the left BRAM's dual ports feed the dual
//! shifters, the LUT BRAM's dual ports serve both lookups, and the right
//! BRAM's dual ports commit both results), so a full 1024-element BRAM is
//! processed in 512 run cycles + pipeline fill — the paper's
//! `C_RUN = 517`.
//!
//! Pipeline (Fig 10): setup (1) → left-BRAM read (2) → shift (3) → LUT
//! lookup (4–5) → write-counter increment (6) → right-BRAM write (7).
//!
//! ### Addressing modes
//!
//! The paper's shift-then-index scheme with a 1024-entry table: the shifted
//! value indexes the LUT directly, wrapped to 10 bits (`AddrMode::Wrap`,
//! paper-accurate). With Q8.7 inputs the wrap aliases `|x| ≥ 2^(9+s-7)`,
//! which breaks saturating activations at the range edges, so the default
//! mode used by the training stack biases the shifted value by half the
//! table and clamps (`AddrMode::Clamp`) — see DESIGN.md §3. Both modes are
//! exercised by tests and the ablation bench.

use super::bram::Bram;
use super::counter::Counter;
use super::trace::Trace;
use super::BRAM_DEPTH;
use crate::isa::ActproOp;
use crate::nn::lut::ActLut;

/// ACTPRO pipeline latency from left-BRAM read issue to right-BRAM commit
/// (Fig 10: read at cycle 2, write at cycle 7).
pub const ACTPRO_LATENCY: u64 = 5;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Idle,
    Run { len: u16, cycle_in_op: u64 },
}

#[derive(Debug, Clone, Copy)]
struct Flight {
    lane0: i16,
    lane1: Option<i16>,
    /// Cycles remaining until commit.
    remaining: u64,
    out_addr: u16,
}

/// One Activation Processor.
#[derive(Debug, Clone)]
pub struct ActPro {
    left: Bram,
    lut_bram: Bram,
    right: Bram,
    read_ctr: Counter,
    write_ctr: Counter,
    lut: ActLut,
    state: State,
    in_flight: Vec<Flight>,
    writes_done: u16,
    run_cycles: u64,
    last_op_cycles: u64,
}

impl ActPro {
    /// New ACTPRO with an activation table loaded (`ACTPRO_WRITE_ACT`).
    pub fn new(lut: ActLut) -> ActPro {
        let mut lut_bram = Bram::new();
        lut_bram.load(0, lut.table());
        ActPro {
            left: Bram::new(),
            lut_bram,
            right: Bram::new(),
            read_ctr: Counter::new(10),
            write_ctr: Counter::new(10),
            lut,
            state: State::Idle,
            in_flight: Vec::new(),
            writes_done: 0,
            run_cycles: 0,
            last_op_cycles: 0,
        }
    }

    /// Replace the activation table (`ACTPRO_WRITE_ACT`, Table 7). Takes
    /// `table.len() / 2` cycles in hardware (dual-port load); charged by
    /// the group model.
    pub fn write_act(&mut self, lut: ActLut) {
        self.lut_bram.load(0, lut.table());
        self.lut = lut;
    }

    /// Load input data (`ACTPRO_WRITE_DATA`): testbench backdoor; the group
    /// charges the 2-elements/cycle write cost.
    pub fn load_input(&mut self, data: &[i16]) {
        assert!(data.len() <= BRAM_DEPTH);
        self.left.load(0, data);
    }

    /// Dump results (`ACTPRO_READ`).
    pub fn dump_result(&self, len: usize) -> Vec<i16> {
        self.right.dump(0, len)
    }

    /// Cycles of the most recently completed run (excludes setup).
    pub fn last_op_cycles(&self) -> u64 {
        self.last_op_cycles
    }

    /// Begin `ACTPRO_RUN` over `len` input elements.
    pub fn begin_run(&mut self, len: u16) {
        assert!(len as usize <= BRAM_DEPTH, "input length {len} exceeds BRAM");
        assert!(len > 0);
        self.state = State::Run { len, cycle_in_op: 0 };
        self.in_flight.clear();
        self.writes_done = 0;
        self.run_cycles = 0;
    }

    /// Step one cycle of `ACTPRO_RUN`; true when complete.
    pub fn step_run(&mut self, mut trace: Option<&mut Trace>) -> bool {
        let (len, cycle_in_op) = match self.state {
            State::Run { len, cycle_in_op } => (len, cycle_in_op),
            _ => panic!("step_run outside ACTPRO_RUN"),
        };
        let cyc = cycle_in_op + 1;
        if let Some(t) = trace.as_deref_mut() {
            t.record(cyc, "state", ActproOp::Run.mnemonic());
        }
        if cyc == 1 {
            // Fig 10 cycle 1: "the control logic sets up the pipeline".
            self.read_ctr.reset();
            self.write_ctr.reset();
            if let Some(t) = trace.as_deref_mut() {
                t.record(cyc, "phase", "setup");
            }
            self.state = State::Run { len, cycle_in_op: cycle_in_op + 1 };
            return false;
        }
        self.run_cycles += 1;

        // Advance in-flight pairs; commit those reaching the right BRAM.
        for f in &mut self.in_flight {
            f.remaining -= 1;
        }
        while let Some(pos) = self.in_flight.iter().position(|f| f.remaining == 0) {
            let f = self.in_flight.remove(pos);
            let y0 = self.lookup(f.lane0);
            self.right.write(0, f.out_addr, y0);
            self.writes_done += 1;
            if let Some(y1_in) = f.lane1 {
                let y1 = self.lookup(y1_in);
                self.right.write(1, f.out_addr + 1, y1);
                self.writes_done += 1;
            }
            if let Some(t) = trace.as_deref_mut() {
                t.record(cyc, "wr_en", 1);
                t.record(cyc, "wr_addr", f.out_addr);
            }
        }
        self.right.clock();

        // Issue the next dual read.
        let i = self.read_ctr.value() * 2;
        if i < len {
            self.left.read(0, i);
            let has_second = i + 1 < len;
            if has_second {
                self.left.read(1, i + 1);
            }
            self.left.clock();
            let lane0 = self.left.dout(0);
            let lane1 = if has_second { Some(self.left.dout(1)) } else { None };
            // Data leaves the read stage now and commits ACTPRO_LATENCY
            // cycles later (read@2 → write@7, Fig 10).
            self.in_flight.push(Flight { lane0, lane1, remaining: ACTPRO_LATENCY, out_addr: i });
            self.read_ctr.clock(true);
            if let Some(t) = trace.as_deref_mut() {
                t.record(cyc, "rd_addr", i);
                t.record(cyc, "shift_in", lane0);
            }
        } else {
            self.left.clock();
        }

        let done = self.writes_done >= len;
        if done {
            self.last_op_cycles = self.run_cycles;
            self.state = State::Idle;
        } else {
            self.state = State::Run { len, cycle_in_op: cycle_in_op + 1 };
        }
        done
    }

    /// The shift → LUT-BRAM lookup datapath for one lane (Fig 9).
    fn lookup(&self, x: i16) -> i16 {
        self.lut.apply_scalar(x)
    }

    /// Run to completion, returning total cycles (including setup).
    pub fn run(&mut self, len: u16) -> u64 {
        self.begin_run(len);
        let mut cycles = 1;
        assert!(!self.step_run(None));
        loop {
            cycles += 1;
            if self.step_run(None) {
                return cycles;
            }
            assert!(cycles < 10_000, "runaway ACTPRO run");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::lut::{ActKind, ActLut, AddrMode};
    use crate::util::Rng;

    fn relu_lut() -> ActLut {
        ActLut::build(ActKind::Relu, false, FixedSpec::PAPER, AddrMode::Clamp, 7)
    }

    #[test]
    fn relu_matches_lut_reference() {
        let mut r = Rng::new(6);
        let xs: Vec<i16> = (0..777).map(|_| r.gen_i16()).collect();
        let lut = relu_lut();
        let mut a = ActPro::new(lut.clone());
        a.load_input(&xs);
        a.run(777);
        let got = a.dump_result(777);
        let want: Vec<i16> = xs.iter().map(|&x| lut.apply_scalar(x)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn full_bram_run_cycles_match_paper_c_run() {
        // C_RUN = 517 for 1024 elements (paper §4.1 activation example):
        // 512 dual-lane reads + 5-cycle latency.
        let mut a = ActPro::new(relu_lut());
        a.load_input(&vec![0; 1024]);
        let total = a.run(1024);
        assert_eq!(a.last_op_cycles(), 517);
        assert_eq!(total, 518); // + setup cycle
    }

    #[test]
    fn fig10_timing_read_at_2_write_at_7() {
        let mut a = ActPro::new(relu_lut());
        a.load_input(&[128, -128]);
        a.begin_run(2);
        let mut tr = Trace::new();
        while !a.step_run(Some(&mut tr)) {}
        assert_eq!(tr.first_cycle_of("rd_addr", "0"), Some(2));
        assert_eq!(tr.first_cycle_of("wr_en", "1"), Some(7));
    }

    #[test]
    fn odd_length_handles_final_single_lane() {
        let xs = vec![10i16, -10, 300];
        let lut = relu_lut();
        let mut a = ActPro::new(lut.clone());
        a.load_input(&xs);
        a.run(3);
        assert_eq!(a.dump_result(3), xs.iter().map(|&x| lut.apply_scalar(x)).collect::<Vec<_>>());
    }

    #[test]
    fn write_act_swaps_table() {
        let relu = relu_lut();
        let drelu = ActLut::build(ActKind::Relu, true, FixedSpec::PAPER, AddrMode::Clamp, 7);
        let mut a = ActPro::new(relu);
        a.write_act(drelu.clone());
        a.load_input(&[256, -256]);
        a.run(2);
        assert_eq!(a.dump_result(2), vec![drelu.apply_scalar(256), drelu.apply_scalar(-256)]);
    }
}
