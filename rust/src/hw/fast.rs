//! Fast functional simulator: bit-exact Matrix Machine numerics without
//! per-flip-flop stepping.
//!
//! Since the perf pass (DESIGN.md §Perf) the training/cluster hot path
//! runs on the compiled [`super::plan::ExecPlan`]; this module remains
//! the **sequential reference executor** — pure data movement +
//! [`crate::fixed::FixedSpec`] arithmetic with no plan-time analysis —
//! against which the plan (and the structural simulator) are asserted
//! equivalent in `rust/tests/sim_equivalence.rs` and the `hw::plan`
//! unit tests.

use crate::assembler::program::{Program, View, Wave};
use crate::fixed::FixedSpec;
use crate::isa::Opcode;

/// Functional state: one lane vector per declared buffer.
#[derive(Debug, Clone)]
pub struct FastSim {
    fixed: FixedSpec,
    buffers: Vec<Vec<i16>>,
    /// Reused lane scratch (perf pass §Perf: exec_wave is allocation-free
    /// on the hot path; strided operands accumulate in place and
    /// elementwise results stage here before scatter).
    scratch: Vec<i16>,
}

impl FastSim {
    /// Allocate buffers for a program (zeroed, or a constant's contents).
    pub fn new(program: &Program) -> FastSim {
        FastSim {
            fixed: program.fixed,
            buffers: program
                .buffers
                .iter()
                .map(|b| match &b.init {
                    Some(d) => {
                        assert_eq!(d.len(), b.len(), "const init length mismatch");
                        d.clone()
                    }
                    None => vec![0i16; b.len()],
                })
                .collect(),
            scratch: Vec::new(),
        }
    }

    /// Dot-product accumulate of two views without materialising them.
    #[inline]
    fn dot_views(&self, a: &View, b: &View) -> i64 {
        let ab = &self.buffers[a.buf];
        let bb = &self.buffers[b.buf];
        if a.stride == 1 && b.stride == 1 {
            let av = &ab[a.offset..a.offset + a.len];
            let bv = &bb[b.offset..b.offset + a.len];
            self.fixed.dot_acc(av, bv)
        } else {
            let mut acc = 0i64;
            let (mut ia, mut ib) = (a.offset, b.offset);
            for _ in 0..a.len {
                acc += ab[ia] as i64 * bb[ib] as i64;
                ia += a.stride;
                ib += b.stride;
            }
            acc
        }
    }

    /// Sum-accumulate of one view.
    #[inline]
    fn sum_view(&self, a: &View) -> i64 {
        let ab = &self.buffers[a.buf];
        if a.stride == 1 {
            ab[a.offset..a.offset + a.len].iter().map(|&x| x as i64).sum()
        } else {
            let mut acc = 0i64;
            let mut ia = a.offset;
            for _ in 0..a.len {
                acc += ab[ia] as i64;
                ia += a.stride;
            }
            acc
        }
    }

    /// Overwrite a buffer's contents (length must match).
    pub fn set_buffer(&mut self, id: usize, data: &[i16]) {
        assert_eq!(self.buffers[id].len(), data.len(), "buffer {id} length mismatch");
        self.buffers[id].copy_from_slice(data);
    }

    /// Read a whole buffer.
    pub fn buffer(&self, id: usize) -> &[i16] {
        &self.buffers[id]
    }

    /// Gather a strided view.
    pub fn gather(&self, v: &View) -> Vec<i16> {
        let buf = &self.buffers[v.buf];
        if v.stride == 1 {
            buf[v.offset..v.offset + v.len].to_vec()
        } else {
            (0..v.len).map(|i| buf[v.offset + i * v.stride]).collect()
        }
    }

    /// Scatter into a strided view.
    pub fn scatter(&mut self, v: &View, data: &[i16]) {
        assert_eq!(data.len(), v.len);
        let buf = &mut self.buffers[v.buf];
        if v.stride == 1 {
            buf[v.offset..v.offset + v.len].copy_from_slice(data);
        } else {
            for (i, &d) in data.iter().enumerate() {
                buf[v.offset + i * v.stride] = d;
            }
        }
    }

    /// Execute one wave functionally (program must have passed `check`).
    /// Allocation-free on the hot path: reductions accumulate straight
    /// from the views; elementwise lanes stage in a reused scratch.
    pub fn exec_wave(&mut self, program: &Program, wave: &Wave) {
        let s = self.fixed;
        match wave.op {
            Opcode::Nop => {}
            Opcode::VectorDotProduct => {
                for lane in &wave.lanes {
                    let b = lane.b.as_ref().expect("checked arity");
                    let acc = self.dot_views(&lane.a, b);
                    let v = s.rescale(acc);
                    self.buffers[lane.out.buf][lane.out.offset] = v;
                }
            }
            Opcode::VectorSummation => {
                for lane in &wave.lanes {
                    let v = s.narrow(self.sum_view(&lane.a));
                    self.buffers[lane.out.buf][lane.out.offset] = v;
                }
            }
            Opcode::ActivationFunction => {
                let lut = &program.luts[wave.lut.expect("checked: ACT wave has LUT")];
                let mut scratch = std::mem::take(&mut self.scratch);
                for lane in &wave.lanes {
                    scratch.clear();
                    let ab = &self.buffers[lane.a.buf];
                    let mut ia = lane.a.offset;
                    for _ in 0..lane.a.len {
                        scratch.push(lut.apply_scalar(ab[ia]));
                        ia += lane.a.stride;
                    }
                    self.scatter(&lane.out, &scratch);
                }
                self.scratch = scratch;
            }
            op => {
                let mut scratch = std::mem::take(&mut self.scratch);
                for lane in &wave.lanes {
                    let b = lane.b.as_ref().expect("checked arity");
                    scratch.clear();
                    let ab = &self.buffers[lane.a.buf];
                    let bb = &self.buffers[b.buf];
                    let (mut ia, mut ib) = (lane.a.offset, b.offset);
                    match op {
                        Opcode::VectorAddition => {
                            for _ in 0..lane.a.len {
                                scratch.push(s.add(ab[ia], bb[ib]));
                                ia += lane.a.stride;
                                ib += b.stride;
                            }
                        }
                        Opcode::VectorSubtraction => {
                            for _ in 0..lane.a.len {
                                scratch.push(s.sub(ab[ia], bb[ib]));
                                ia += lane.a.stride;
                                ib += b.stride;
                            }
                        }
                        Opcode::ElementMultiplication => {
                            for _ in 0..lane.a.len {
                                scratch.push(s.mul(ab[ia], bb[ib]));
                                ia += lane.a.stride;
                                ib += b.stride;
                            }
                        }
                        _ => unreachable!(),
                    }
                    self.scatter(&lane.out, &scratch);
                }
                self.scratch = scratch;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{BufKind, LaneOp, Step};
    use crate::nn::lut::{ActKind, ActLut, AddrMode};
    use crate::util::Rng;

    const S: FixedSpec = FixedSpec::PAPER;

    #[test]
    fn wave_execution_matches_fixed_reference() {
        let mut p = Program::new("t", S);
        let a = p.buffer("a", 32, 1, BufKind::Input);
        let b = p.buffer("b", 32, 1, BufKind::Input);
        let o = p.buffer("o", 32, 1, BufKind::Output);
        let d = p.buffer("d", 1, 1, BufKind::Output);
        let mut r = Rng::new(10);
        let av: Vec<i16> = (0..32).map(|_| r.gen_i16()).collect();
        let bv: Vec<i16> = (0..32).map(|_| r.gen_i16()).collect();
        let mut sim = FastSim::new(&p);
        sim.set_buffer(a, &av);
        sim.set_buffer(b, &bv);
        for (op, out, want) in [
            (Opcode::VectorAddition, o, S.vadd(&av, &bv)),
            (Opcode::VectorSubtraction, o, S.vsub(&av, &bv)),
            (Opcode::ElementMultiplication, o, S.vmul(&av, &bv)),
            (Opcode::VectorDotProduct, d, vec![S.dot(&av, &bv)]),
        ] {
            let out_len = if out == d { 1 } else { 32 };
            let w = Wave {
                op,
                vec_len: 32,
                lut: None,
                lanes: vec![LaneOp {
                    a: View::all(a, 32),
                    b: Some(View::all(b, 32)),
                    out: View::all(out, out_len),
                }],
            };
            sim.exec_wave(&p, &w);
            assert_eq!(sim.buffer(out), want.as_slice(), "{op}");
        }
    }

    #[test]
    fn strided_gather_scatter_walks_columns() {
        // 3x4 row-major matrix; column 1 = lanes 1,5,9.
        let mut p = Program::new("t", S);
        let m = p.buffer("m", 3, 4, BufKind::Input);
        let mut sim = FastSim::new(&p);
        sim.set_buffer(m, &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]);
        let col1 = View { buf: m, offset: 1, len: 3, stride: 4 };
        assert_eq!(sim.gather(&col1), vec![1, 5, 9]);
        sim.scatter(&col1, &[-1, -5, -9]);
        assert_eq!(sim.buffer(m), &[0, -1, 2, 3, 4, -5, 6, 7, 8, -9, 10, 11]);
    }

    #[test]
    fn activation_wave_uses_lut() {
        let mut p = Program::new("t", S);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let lut_id =
            p.lut(ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7));
        p.steps.push(Step::LoadLut(lut_id));
        let mut sim = FastSim::new(&p);
        sim.set_buffer(x, &[-300, -1, 128, 300]);
        let w = Wave {
            op: Opcode::ActivationFunction,
            vec_len: 4,
            lut: Some(lut_id),
            lanes: vec![LaneOp { a: View::all(x, 4), b: None, out: View::all(x, 4) }],
        };
        sim.exec_wave(&p, &w);
        let lut = &p.luts[lut_id];
        assert_eq!(sim.buffer(x), lut.apply(&[-300, -1, 128, 300]).as_slice());
    }
}
