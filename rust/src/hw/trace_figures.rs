//! Regeneration of the paper's timing diagrams (Figs 7, 8, 10) from the
//! structural simulator's traces — experiment ids E-F7 / E-F8 / E-F10 in
//! DESIGN.md. Used by `mfnn traces` and `examples/timing_traces.rs`.

use super::actpro::ActPro;
use super::mvm::Mvm;
use super::trace::Trace;
use crate::fixed::FixedSpec;
use crate::isa::MvmOp;
use crate::nn::lut::{ActKind, ActLut, AddrMode};

/// Fig 7: the MVM write timing — setup cycle, then two elements committed
/// per cycle through both BRAM ports.
pub fn fig7_mvm_write() -> String {
    // The write path is driven by the group; the interesting signals are
    // the per-cycle commits. We reproduce the figure's narrative.
    let mut m = Mvm::new(FixedSpec::PAPER);
    let mut t = Trace::new();
    m.begin_write();
    t.record(1, "state", "MVM_WRITE");
    t.record(1, "phase", "setup");
    let data = [(10i16, 11i16), (12, 13), (14, 15)];
    m.write_pair(0, 0, 0, 0, false); // setup cycle (no commit)
    for (i, (d0, d1)) in data.iter().enumerate() {
        let cyc = (i + 2) as u64;
        let a0 = (i * 2) as u16;
        m.write_pair(a0, *d0, a0 + 1, *d1, false);
        t.record(cyc, "state", "MVM_WRITE");
        t.record(cyc, "phase", "commit");
        t.record(cyc, "input_addr0", a0);
        t.record(cyc, "input_data0", *d0);
        t.record(cyc, "input_addr1", a0 + 1);
        t.record(cyc, "input_data1", *d1);
    }
    m.end_write();
    format!(
        "Fig 7 — MVM write timing (setup at cycle 1; both ports commit in\n\
         parallel from cycle 2, 2 elements/cycle):\n\n{}",
        t.render(1, 4)
    )
}

/// Fig 8: the MVM vector addition pipeline — setup(1), BRAM read issue(2),
/// DSP 6-stage pipeline, `P` at cycle 8, right-BRAM write at cycle 9.
pub fn fig8_mvm_vec_add() -> String {
    let mut m = Mvm::new(FixedSpec::PAPER);
    m.load_column(false, &[5, 6, 7, 8]);
    m.load_column(true, &[1, 1, 1, 1]);
    m.begin_compute(MvmOp::VecAdd, 4, false);
    let mut t = Trace::new();
    while !m.step_compute(Some(&mut t)) {}
    format!(
        "Fig 8 — MVM vector addition (A=[5,6,7,8], B=[1,1,1,1]; read at\n\
         cycle 2, P output at cycle 8, right-BRAM write at cycle 9;\n\
         1 result/cycle once the pipeline fills):\n\n{}",
        t.render(1, t.max_cycle())
    )
}

/// Fig 10: the ACTPRO ReLU pipeline — setup(1), left-BRAM read(2), dual
/// shift(3), LUT lookup(4–5), write-counter(6), right-BRAM write(7).
pub fn fig10_actpro_relu() -> String {
    let lut = ActLut::build(ActKind::Relu, false, FixedSpec::PAPER, AddrMode::Wrap, 7);
    let mut a = ActPro::new(lut);
    a.load_input(&[256, -256, 384, -1, 512, 0]);
    a.begin_run(6);
    let mut t = Trace::new();
    while !a.step_run(Some(&mut t)) {}
    format!(
        "Fig 10 — ACTPRO executing ReLU (inputs [2.0, -2.0, 3.0, -2^-7,\n\
         4.0, 0] in Q8.7; dual lanes: read at cycle 2, result written at\n\
         cycle 7, 2 elements/cycle):\n\n{}",
        t.render(1, t.max_cycle())
    )
}

/// All three figures concatenated.
pub fn all_figures() -> String {
    format!("{}\n{}\n{}", fig7_mvm_write(), fig8_mvm_vec_add(), fig10_actpro_relu())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_shows_parallel_commits() {
        let s = fig7_mvm_write();
        assert!(s.contains("input_data0"), "{s}");
        assert!(s.contains("input_data1"));
        assert!(s.contains("setup"));
    }

    #[test]
    fn fig8_timing_landmarks() {
        let s = fig8_mvm_vec_add();
        // P first updates at cycle 8 with 5+1=6; write at 9.
        assert!(s.contains("dsp_p"), "{s}");
        assert!(s.contains("wr_en"));
    }

    #[test]
    fn fig10_shows_relu_semantics() {
        let s = fig10_actpro_relu();
        assert!(s.contains("rd_addr"), "{s}");
        assert!(s.contains("wr_en"));
    }

    #[test]
    fn all_figures_nonempty() {
        let s = all_figures();
        assert!(s.contains("Fig 7") && s.contains("Fig 8") && s.contains("Fig 10"));
    }
}
