//! Circular FIFO ring connecting the global controller and the processor
//! groups (paper §4, Fig 4).
//!
//! "The global controller writes microcodes and data to a circular FIFO.
//! The FIFO's purpose is to distribute the microcodes and data to each
//! processor group. The FIFO also collects outputs of each processor
//! group. Moreover, the FIFO reduces the propagation delay of the signals."
//!
//! We model the ring as `n_stations` registered hops (station 0 = global
//! controller, stations `1..=G` = processor groups): a token injected at
//! station `s` for destination `d` takes `ring_distance(s, d)` cycles and
//! one slot of the bounded buffer. The bounded capacity is what gives the
//! cluster/machine layers their backpressure semantics.

use std::collections::VecDeque;

/// A token travelling the ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token<T> {
    /// Destination station.
    pub dest: usize,
    /// Remaining hop count.
    pub hops_left: usize,
    /// Payload (microcode word or data beat).
    pub payload: T,
}

/// Bounded ring FIFO with per-cycle hop progression.
#[derive(Debug, Clone)]
pub struct RingFifo<T> {
    n_stations: usize,
    capacity: usize,
    in_flight: VecDeque<Token<T>>,
    delivered: Vec<VecDeque<T>>,
    /// Total tokens ever enqueued (for stats).
    pub enqueued: u64,
    /// Cycles advanced (for stats).
    pub cycles: u64,
}

impl<T> RingFifo<T> {
    /// A ring with `n_stations` stations and `capacity` in-flight slots.
    pub fn new(n_stations: usize, capacity: usize) -> RingFifo<T> {
        assert!(n_stations >= 1);
        assert!(capacity >= 1);
        RingFifo {
            n_stations,
            capacity,
            in_flight: VecDeque::new(),
            delivered: (0..n_stations).map(|_| VecDeque::new()).collect(),
            enqueued: 0,
            cycles: 0,
        }
    }

    /// Unidirectional ring distance from `src` to `dest`.
    pub fn ring_distance(&self, src: usize, dest: usize) -> usize {
        (dest + self.n_stations - src) % self.n_stations
    }

    /// Try to inject a token at `src` for `dest`; `Err(payload)` when the
    /// ring is full (backpressure).
    pub fn push(&mut self, src: usize, dest: usize, payload: T) -> Result<(), T> {
        assert!(src < self.n_stations && dest < self.n_stations);
        if self.in_flight.len() >= self.capacity {
            return Err(payload);
        }
        let hops = self.ring_distance(src, dest);
        if hops == 0 {
            self.delivered[dest].push_back(payload);
        } else {
            self.in_flight.push_back(Token { dest, hops_left: hops, payload });
        }
        self.enqueued += 1;
        Ok(())
    }

    /// Advance one cycle: every in-flight token moves one hop.
    pub fn clock(&mut self) {
        self.cycles += 1;
        let mut still = VecDeque::with_capacity(self.in_flight.len());
        while let Some(mut t) = self.in_flight.pop_front() {
            t.hops_left -= 1;
            if t.hops_left == 0 {
                self.delivered[t.dest].push_back(t.payload);
            } else {
                still.push_back(t);
            }
        }
        self.in_flight = still;
    }

    /// Pop a delivered token at a station.
    pub fn pop(&mut self, station: usize) -> Option<T> {
        self.delivered[station].pop_front()
    }

    /// Tokens currently in flight.
    pub fn in_flight_len(&self) -> usize {
        self.in_flight.len()
    }

    /// Delivered-but-unconsumed count at a station.
    pub fn pending_at(&self, station: usize) -> usize {
        self.delivered[station].len()
    }

    /// Worst-case delivery latency (full ring traversal).
    pub fn worst_latency(&self) -> usize {
        self.n_stations - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_after_ring_distance_cycles() {
        let mut f: RingFifo<u32> = RingFifo::new(5, 16);
        f.push(0, 3, 42).unwrap();
        for _ in 0..2 {
            f.clock();
            assert_eq!(f.pop(3), None);
        }
        f.clock(); // 3rd hop
        assert_eq!(f.pop(3), Some(42));
    }

    #[test]
    fn wraparound_distance() {
        let f: RingFifo<()> = RingFifo::new(4, 4);
        assert_eq!(f.ring_distance(3, 1), 2);
        assert_eq!(f.ring_distance(1, 3), 2);
        assert_eq!(f.ring_distance(2, 2), 0);
    }

    #[test]
    fn self_delivery_is_immediate() {
        let mut f: RingFifo<u8> = RingFifo::new(3, 2);
        f.push(1, 1, 9).unwrap();
        assert_eq!(f.pop(1), Some(9));
    }

    #[test]
    fn backpressure_when_full() {
        let mut f: RingFifo<u8> = RingFifo::new(4, 2);
        f.push(0, 1, 1).unwrap();
        f.push(0, 2, 2).unwrap();
        assert_eq!(f.push(0, 3, 3), Err(3));
        f.clock(); // token 1 arrives
        assert_eq!(f.pop(1), Some(1));
        assert!(f.push(0, 3, 3).is_ok());
    }

    #[test]
    fn fifo_order_preserved_per_destination() {
        let mut f: RingFifo<u8> = RingFifo::new(3, 8);
        f.push(0, 2, 1).unwrap();
        f.push(0, 2, 2).unwrap();
        f.clock();
        f.push(0, 2, 3).unwrap();
        f.clock();
        f.clock();
        assert_eq!(f.pop(2), Some(1));
        assert_eq!(f.pop(2), Some(2));
        assert_eq!(f.pop(2), Some(3));
    }

    #[test]
    fn ring_distance_to_self_is_zero_at_every_station() {
        let f: RingFifo<()> = RingFifo::new(6, 4);
        for s in 0..6 {
            assert_eq!(f.ring_distance(s, s), 0, "station {s}");
        }
        // degenerate single-station ring
        let one: RingFifo<()> = RingFifo::new(1, 1);
        assert_eq!(one.ring_distance(0, 0), 0);
        assert_eq!(one.worst_latency(), 0);
    }

    #[test]
    fn capacity_one_backpressure_roundtrip() {
        let mut f: RingFifo<u8> = RingFifo::new(3, 1);
        f.push(0, 1, 7).unwrap();
        assert_eq!(f.push(0, 2, 9), Err(9), "single slot must backpressure");
        f.clock(); // 7 delivered at station 1
        assert!(f.push(0, 2, 9).is_ok(), "slot must free after delivery");
        assert_eq!(f.pop(1), Some(7));
        f.clock();
        f.clock();
        assert_eq!(f.pop(2), Some(9));
        assert_eq!(f.in_flight_len(), 0);
    }

    #[test]
    fn wraparound_delivery_order_across_station_zero() {
        // src 3 → dest 1 on a 4-ring wraps through station 0 (2 hops);
        // a direct 1-hop token injected at the same time lands first.
        let mut f: RingFifo<u8> = RingFifo::new(4, 8);
        f.push(3, 1, 10).unwrap();
        f.push(0, 1, 20).unwrap();
        f.clock();
        assert_eq!(f.pop(1), Some(20), "direct token arrives after 1 hop");
        assert_eq!(f.pop(1), None, "wrapped token still in flight");
        f.clock();
        assert_eq!(f.pop(1), Some(10), "wrapped token arrives after 2 hops");
    }

    #[test]
    fn full_ring_stalls_then_drains_completely() {
        let (n, cap) = (5usize, 4usize);
        let mut f: RingFifo<usize> = RingFifo::new(n, cap);
        for i in 0..cap {
            // destinations 1..=4: hop counts 1..=worst_latency
            f.push(0, 1 + (i % (n - 1)), i).unwrap();
        }
        assert_eq!(f.in_flight_len(), cap);
        assert_eq!(f.push(0, 1, 99), Err(99), "full ring must stall injection");
        for _ in 0..f.worst_latency() {
            f.clock();
        }
        assert_eq!(f.in_flight_len(), 0, "ring must drain within worst_latency");
        let mut delivered = 0usize;
        for s in 0..n {
            while f.pop(s).is_some() {
                delivered += 1;
            }
        }
        assert_eq!(delivered, cap, "every stalled-behind token must land");
        assert!(f.push(0, 1, 99).is_ok(), "drained ring accepts again");
    }

    #[test]
    fn stats_count() {
        let mut f: RingFifo<u8> = RingFifo::new(2, 4);
        f.push(0, 1, 1).unwrap();
        f.clock();
        assert_eq!(f.enqueued, 1);
        assert_eq!(f.cycles, 1);
        assert_eq!(f.in_flight_len(), 0);
        assert_eq!(f.pending_at(1), 1);
    }
}
