//! Small shared utilities: deterministic PRNG, simple leveled logging, and
//! misc numeric helpers.
//!
//! The sandbox has no `rand` crate, so [`Rng`] implements xorshift64* +
//! SplitMix64 seeding from scratch. Everything that needs randomness in the
//! crate (datasets, property tests, workload generators) goes through this
//! type so runs are reproducible from a single `u64` seed.

mod rng;
pub use rng::Rng;

use std::sync::atomic::{AtomicU8, Ordering};

/// Log levels, lowest = most verbose.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // Info

/// Set the global log level.
pub fn set_log_level(level: Level) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn log_level() -> Level {
    match LOG_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Trace,
        1 => Level::Debug,
        2 => Level::Info,
        3 => Level::Warn,
        _ => Level::Error,
    }
}

/// Emit a log line if `level` is enabled. Prefer the `log_*!` macros.
pub fn log(level: Level, target: &str, msg: &str) {
    if level >= log_level() {
        eprintln!("[{:<5}] {}: {}", format!("{level:?}").to_uppercase(), target, msg);
    }
}

/// `log_info!(target, fmt, args...)`
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log($crate::util::Level::Info, $target, &format!($($arg)*))
    };
}
/// `log_debug!(target, fmt, args...)`
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log($crate::util::Level::Debug, $target, &format!($($arg)*))
    };
}
/// `log_warn!(target, fmt, args...)`
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log($crate::util::Level::Warn, $target, &format!($($arg)*))
    };
}

/// Integer ceiling division for unsigned 64-bit values.
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Format a cycle count / large integer with thousands separators.
pub fn fmt_thousands(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i != 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }

    #[test]
    fn thousands_formatting() {
        assert_eq!(fmt_thousands(0), "0");
        assert_eq!(fmt_thousands(999), "999");
        assert_eq!(fmt_thousands(1000), "1,000");
        assert_eq!(fmt_thousands(4238336), "4,238,336");
    }

    #[test]
    fn log_level_roundtrip() {
        let old = log_level();
        set_log_level(Level::Warn);
        assert_eq!(log_level(), Level::Warn);
        set_log_level(old);
    }
}
