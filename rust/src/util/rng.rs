//! Deterministic PRNG: SplitMix64 for seeding, xorshift64* for the stream.
//! Not cryptographic; used for datasets, property tests, and workloads.

/// A small, fast, deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. Any seed (including 0) is valid: the seed is
    /// pre-mixed with SplitMix64 so the xorshift state is never zero.
    pub fn new(seed: u64) -> Self {
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        s = (s ^ (s >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        s = (s ^ (s >> 27)).wrapping_mul(0x94D049BB133111EB);
        s ^= s >> 31;
        Rng { state: s | 1 }
    }

    /// Next raw 64-bit value (xorshift64*).
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Multiply-shift bounded sampling (Lemire); the slight modulo bias of
        // naive `% n` would be fine for our uses, but this is just as cheap.
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.gen_range(span) as i64
    }

    /// Uniform `i16` over the full range.
    pub fn gen_i16(&mut self) -> i16 {
        self.next_u64() as i16
    }

    /// Uniform float in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// True with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element by reference. Panics on empty slices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// Split a child RNG (useful for parallel deterministic streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Advance the stream by `n` draws without using them. Every
    /// single-value generator (`gen_range`, `gen_f64`, `gen_i16`, …)
    /// consumes exactly one draw, so `skip(n)` puts the stream where it
    /// would be after `n` such calls — what deterministic
    /// checkpoint/resume uses to fast-forward a batch sampler.
    pub fn skip(&mut self, n: u64) {
        for _ in 0..n {
            self.next_u64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
            let w = r.gen_range_i64(-5, 5);
            assert!((-5..=5).contains(&w));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.gen_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn skip_matches_discarded_draws() {
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        for _ in 0..13 {
            a.gen_range(10);
        }
        b.skip(13);
        assert_eq!(a.next_u64(), b.next_u64());
        // gen_f64 / gen_i16 / gen_bool are also exactly one draw each
        let mut c = Rng::new(5);
        let mut d = Rng::new(5);
        c.gen_f64();
        c.gen_i16();
        c.gen_bool(0.5);
        d.skip(3);
        assert_eq!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
