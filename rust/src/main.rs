//! `mfnn` — the command-line launcher for the Matrix Assembler, the
//! simulated Matrix Machine, and the multi-FPGA cluster runtime.
//!
//! ```text
//! mfnn assemble  <net.nnasm> [--device P] [--vhdl DIR] [--print]
//! mfnn run       <net.nnasm> [--device P] [--verify] [--seed N]
//! mfnn train     <config.toml>
//! mfnn serve-sim [--requests N] [--seed S] [--nets M] [--boards B] [--max-batch K]
//!                [--chaos] [--fault-seed S] [--check-determinism]
//! mfnn fuzz      [--cases N] [--seed S] [--corpus FILE] [--plant-divergence]
//! mfnn lint      [net.nnasm] [--device P] [--batch N] [--level L] [--bound B] [--json]
//! mfnn plan      [--device P] [--batch N] [--report] [--out FILE]
//! mfnn tables    [--which t2|t3|t8|alloc|perf|all]
//! mfnn traces
//! mfnn golden    [--dir artifacts]
//! ```

use mfnn::asm::lower_file;
use mfnn::assembler::vhdl;
use mfnn::cli::{Args, Spec};
use mfnn::cluster::{ClusterConfig, SyncPolicy, SystemBus};
use mfnn::config::Config;
use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, MemPlan};
use mfnn::isa::Width;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::perf::catalog::{FpgaPart, CATALOG};
use mfnn::perf::group::{OpClass, PerfModel};
use mfnn::report::{f, Table};
#[cfg(feature = "xla")]
use mfnn::runtime::{GoldenModel, Runtime};
use mfnn::session::NetJob;
use mfnn::util::Rng;
use mfnn::{CompileOptions, Compiler, Session, Target};
use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd {
        "assemble" => cmd_assemble(&rest),
        "run" => cmd_run(&rest),
        "train" => cmd_train(&rest),
        "serve-sim" => cmd_serve_sim(&rest),
        "fuzz" => cmd_fuzz(&rest),
        "lint" => cmd_lint(&rest),
        "plan" => cmd_plan(&rest),
        "tables" => cmd_tables(&rest),
        "traces" => cmd_traces(&rest),
        "golden" => cmd_golden(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    format!(
        "mfnn {} — multiple neural networks on multiple (simulated) FPGAs\n\n\
         COMMANDS:\n\
         \x20 assemble <net.nnasm>   parse+lower a net; optional VHDL emission\n\
         \x20 run      <net.nnasm>   execute a net on one simulated board\n\
         \x20 train    <cfg.toml>    run a training cluster from a launcher config\n\
         \x20 serve-sim              drive the batched serving runtime with synthetic load\n\
         \x20 fuzz                   differential-fuzz every simulator fidelity level\n\
         \x20 lint                   static program checker: dataflow, ranges, ring, hazards\n\
         \x20 plan                   static memory-planner report: packed vs planned BRAM per net\n\
         \x20 tables                 regenerate the paper's tables (2,3,8,alloc,perf)\n\
         \x20 traces                 print the Fig 7/8/10 timing diagrams\n\
         \x20 golden                 cross-check simulator vs JAX/Pallas artifacts\n",
        mfnn::VERSION
    )
}

fn parse_or_help(spec: &Spec, rest: &[String], cmd: &str, about: &str) -> Result<Args, String> {
    if rest.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", spec.help(cmd, about));
        std::process::exit(0);
    }
    spec.parse(rest.iter().cloned()).map_err(|e| e.to_string())
}

fn device_arg(args: &Args) -> Result<&'static FpgaPart, String> {
    let name = args.str_or("device", "XC7S75-2");
    FpgaPart::by_name(&name).ok_or_else(|| format!("unknown FPGA part {name:?}"))
}

// ----------------------------------------------------------------- assemble

fn cmd_assemble(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new()
        .opt("device", "target FPGA part", Some("XC7S75-2"))
        .opt("vhdl", "emit the generated VHDL bundle into this directory", None)
        .flag("print", "print the encoded instruction stream")
        .pos("net", "assembly source (.nnasm)", true);
    let args = parse_or_help(&spec, rest, "mfnn assemble", "Run the Matrix Assembler")?;
    let path = args.positional("net").unwrap();
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let nets = lower_file(&text).map_err(|e| e.to_string())?;
    let part = device_arg(&args)?;
    let device = FpgaDevice::new(part);
    println!(
        "device {}: {} MVM_PG + {} ACTPRO_PG (Eqns 3-4)",
        part.name, device.mvm_groups, device.actpro_groups
    );
    for net in &nets {
        let p = &net.mlp.program;
        println!(
            "net {:?}: {} layers, batch {}, {} buffers, {} waves, {} lane-ops{}",
            net.spec.name,
            net.spec.layers.len(),
            net.batch,
            p.buffers.len(),
            p.waves().count(),
            p.total_lane_ops(),
            if net.train { " (training)" } else { "" },
        );
        if args.flag("print") {
            let instrs = p
                .encode(Width::W32, device.mvm_groups as usize, device.actpro_groups as usize)
                .map_err(|e| e.to_string())?;
            for (i, ins) in instrs.iter().enumerate() {
                println!("  [{i:>3}] {:#010x}  {}", ins.encode(Width::W32).unwrap(), ins);
            }
        }
        if let Some(dir) = args.get("vhdl") {
            let bundle = vhdl::generate(part, Some(p));
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            for (name, body) in &bundle.files {
                let out = Path::new(dir).join(format!("{}_{name}", net.spec.name));
                std::fs::write(&out, body).map_err(|e| e.to_string())?;
                println!("  wrote {}", out.display());
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------- run

fn cmd_run(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new()
        .opt("device", "target FPGA part", Some("XC7S75-2"))
        .opt("seed", "RNG seed for random bindings", Some("1"))
        .flag("verify", "verify every wave on the structural simulator")
        .pos("net", "assembly source (.nnasm)", true);
    let args = parse_or_help(&spec, rest, "mfnn run", "Execute a net on one simulated board")?;
    let path = args.positional("net").unwrap();
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let part = device_arg(&args)?;
    let seed: u64 = args.parse_or("seed", 1).map_err(|e| e.to_string())?;
    let compiler = Compiler::new();
    let artifacts = compiler.compile_asm(&text).map_err(|e| e.to_string())?;
    for artifact in &artifacts {
        let dev = FpgaDevice::new(part);
        let mut session = Session::open(Arc::clone(artifact), Target::Board(dev))
            .map_err(|e| e.to_string())?;
        // Bind random data to every host-facing tensor.
        let mut r = Rng::new(seed);
        let fsp = artifact.fixed();
        for h in artifact.tensors() {
            use mfnn::assembler::program::BufKind::*;
            if matches!(h.kind(), Input | Weight | Bias | Target) {
                let data: Vec<i16> =
                    (0..h.len()).map(|_| fsp.from_f64((r.gen_f64() - 0.5) * 1.5)).collect();
                session.write(&h, &data).map_err(|e| e.to_string())?;
            }
        }
        let stats = if args.flag("verify") {
            session.step_verified().map_err(|e| e.to_string())?
        } else {
            session.step()
        };
        println!(
            "net {:?}: {} cycles (dma {} + compute {} + lut {} + ring {}), \
             {:.3} ms simulated, {} lane-ops ({}/s)",
            artifact.name(),
            stats.cycles,
            stats.dma_cycles,
            stats.compute_cycles,
            stats.lut_cycles,
            stats.ring_cycles,
            stats.seconds(&dev) * 1e3,
            stats.lane_ops,
            mfnn::bench::fmt_count(stats.lane_ops_per_sec(&dev)),
        );
    }
    Ok(())
}

// -------------------------------------------------------------------- train

fn cmd_train(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new()
        .opt(
            "checkpoint-every",
            "capture a deterministic checkpoint every N steps (0 = off)",
            Some("0"),
        )
        .opt("checkpoint-dir", "directory for per-job <name>.mfck snapshots", Some("checkpoints"))
        .flag("resume", "resume each job from <checkpoint-dir>/<name>.mfck when present")
        .pos("config", "launcher config (.toml)", true);
    let args = parse_or_help(&spec, rest, "mfnn train", "Run a training cluster from a config")?;
    let path = args.positional("config").unwrap();
    let cfg = Config::from_file(Path::new(path)).map_err(|e| e.to_string())?;
    let every: usize = args.parse_or("checkpoint-every", 0).map_err(|e| e.to_string())?;
    let ckpt_dir = args.str_or("checkpoint-dir", "checkpoints");
    let compiler = Compiler::new();
    let (mut ccfg, mut jobs) = jobs_from_config(&compiler, &cfg)?;
    ccfg.recovery.checkpoint_every = every;
    if args.flag("resume") {
        for job in &mut jobs {
            let path = Path::new(&ckpt_dir).join(format!("{}.mfck", job.artifact.name()));
            if path.exists() {
                let ck = mfnn::TrainCheckpoint::load(&path)
                    .map_err(|e| format!("{}: {e}", path.display()))?;
                println!(
                    "resuming {:?} from step {} ({})",
                    job.artifact.name(),
                    ck.steps_done,
                    path.display()
                );
                job.resume = Some(ck);
            }
        }
    }
    let report = Session::train_many(&ccfg, &jobs).map_err(|e| e.to_string())?;
    if every > 0 {
        std::fs::create_dir_all(&ckpt_dir).map_err(|e| format!("{ckpt_dir}: {e}"))?;
        for jr in &report.results {
            if let Some(ck) = jr.checkpoints.last() {
                let path = Path::new(&ckpt_dir).join(format!("{}.mfck", jr.name));
                ck.save(&path).map_err(|e| format!("{}: {e}", path.display()))?;
                println!(
                    "checkpoint: {:?} at step {} → {}",
                    jr.name,
                    ck.steps_done,
                    path.display()
                );
            }
        }
    }
    let mut t = Table::new(vec!["job", "boards", "steps", "accuracy", "sim compute", "sim bus"])
        .with_title(format!(
            "cluster: {} boards ({:?}), makespan {:.3} ms simulated",
            ccfg.boards,
            report.placement.mode,
            report.makespan_s * 1e3
        ))
        .numeric();
    for jr in &report.results {
        t.row(vec![
            jr.name.clone(),
            format!("{:?}", jr.boards),
            jr.steps.to_string(),
            f(jr.accuracy, 3),
            format!("{:.3} ms", jr.sim_compute_s * 1e3),
            format!("{:.3} ms", jr.sim_bus_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    println!("metrics: {:?}", report.metrics);
    Ok(())
}

/// Build cluster + session jobs from a launcher config (see
/// `configs/demo.toml`).
fn jobs_from_config(
    compiler: &Compiler,
    cfg: &Config,
) -> Result<(ClusterConfig, Vec<NetJob>), String> {
    let ccfg = ClusterConfig {
        boards: cfg.int_or("cluster.boards", 2) as usize,
        device: cfg.str_or("cluster.device", "XC7S75-2"),
        bus: SystemBus {
            bandwidth_bps: cfg.float_or("cluster.bus_bandwidth_bps", 125e6),
            latency_s: cfg.float_or("cluster.bus_latency_s", 50e-6),
        },
        sync_every: cfg.int_or("cluster.sync_every", 20) as usize,
        sync: SyncPolicy::parse(&cfg.str_or("cluster.sync", "star"))
            .ok_or("cluster.sync invalid (star|ring|bounded-stale[:N])")?,
        ..ClusterConfig::default()
    };
    let names =
        cfg.get_str_array("jobs.names").ok_or("config needs jobs.names = [\"a\", ...]")?;
    let mut jobs = Vec::new();
    for name in &names {
        let pfx = format!("job.{name}");
        let dims: Vec<usize> = cfg
            .get_int_array(&format!("{pfx}.dims"))
            .ok_or(format!("{pfx}.dims missing"))?
            .into_iter()
            .map(|d| d as usize)
            .collect();
        let frac = cfg.int_or(&format!("{pfx}.frac_bits"), 10) as u32;
        let mut fixed = FixedSpec::q(frac);
        if cfg.bool_or(&format!("{pfx}.saturate"), true) {
            fixed = fixed.saturating();
        }
        let act = ActKind::parse(&cfg.str_or(&format!("{pfx}.act"), "relu"))
            .ok_or(format!("{pfx}.act invalid"))?;
        let out_act = ActKind::parse(&cfg.str_or(&format!("{pfx}.out_act"), "identity"))
            .ok_or(format!("{pfx}.out_act invalid"))?;
        let spec =
            MlpSpec::from_dims(name, &dims, act, out_act, fixed, LutParams::training(fixed))
                .map_err(|e| e.to_string())?;
        let ds_name = cfg.str_or(&format!("{pfx}.dataset"), "blobs");
        let n = cfg.int_or(&format!("{pfx}.samples"), 256) as usize;
        let seed = cfg.int_or(&format!("{pfx}.seed"), 1) as u64;
        let ds =
            dataset::by_name(&ds_name, n, seed).ok_or(format!("unknown dataset {ds_name:?}"))?;
        let (train, test) = ds.split(0.8, &mut Rng::new(seed));
        let batch = cfg.int_or(&format!("{pfx}.batch"), 16) as usize;
        let lr = cfg.float_or(&format!("{pfx}.lr"), 1.0 / 128.0);
        let artifact = compiler
            .compile_spec(&spec, &CompileOptions::training(batch, lr))
            .map_err(|e| e.to_string())?;
        jobs.push(NetJob {
            artifact,
            cfg: TrainConfig {
                batch,
                lr,
                steps: cfg.int_or(&format!("{pfx}.steps"), 300) as usize,
                seed,
                log_every: cfg.int_or(&format!("{pfx}.log_every"), 25) as usize,
            },
            train: Arc::new(train),
            test: Arc::new(test),
        });
    }
    Ok((ccfg, jobs))
}

// ---------------------------------------------------------------- serve-sim

/// The serve-sim fleet: `nets` small distinct MLPs with seeded random
/// parameters, compiled for serving at `max_batch`.
#[allow(clippy::type_complexity)]
fn serve_sim_nets(
    compiler: &Compiler,
    nets: usize,
    max_batch: usize,
    seed: u64,
) -> Result<Vec<(Arc<mfnn::Artifact>, Vec<Vec<i16>>, Vec<Vec<i16>>)>, String> {
    let fixed = FixedSpec::q(10).saturating();
    let mut out = Vec::with_capacity(nets);
    for j in 0..nets {
        let dims = [3 + j % 4, 8 + 4 * (j % 3), 2 + j % 3];
        let spec = MlpSpec::from_dims(
            &format!("net{j}"),
            &dims,
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .map_err(|e| e.to_string())?;
        let (w, b) = mfnn::serve::seeded_params(&spec, seed ^ 0xA11CE ^ j as u64);
        let artifact = compiler
            .compile_spec(&spec, &CompileOptions::serving(max_batch))
            .map_err(|e| e.to_string())?;
        out.push((artifact, w, b));
    }
    Ok(out)
}

fn cmd_serve_sim(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new()
        .opt("requests", "total requests in the synthetic workload", Some("256"))
        .opt("seed", "workload seed (arrivals, rows, net mix, params)", Some("0"))
        .opt("nets", "registered nets (distinct shapes)", Some("3"))
        .opt("boards", "boards in the serving pool", Some("2"))
        .opt("device", "FPGA part the pool simulates", Some("XC7S75-2"))
        .opt("max-batch", "micro-batcher flush threshold / top ladder bucket", Some("8"))
        .opt("max-wait", "micro-batcher flush deadline in simulated cycles", Some("64"))
        .opt("queue-cap", "per-net admission limit (typed sheds beyond)", Some("1024"))
        .opt("rate", "mean request inter-arrival gap in simulated cycles", Some("8"))
        .opt("metrics-out", "write the metrics JSON here", Some("serve_metrics.json"))
        .opt("fault-seed", "chaos fault-plan seed (default: the workload seed)", None)
        .flag("chaos", "degraded mode: SLO-annotated load + a survivable injected fault plan")
        .flag("check-determinism", "run the workload twice and require identical outcomes");
    let args = parse_or_help(
        &spec,
        rest,
        "mfnn serve-sim",
        "Simulate multi-tenant batched inference serving over the board pool",
    )?;
    let requests: usize = args.parse_or("requests", 256).map_err(|e| e.to_string())?;
    let seed: u64 = args.parse_or("seed", 0).map_err(|e| e.to_string())?;
    let nets: usize = args.parse_or("nets", 3).map_err(|e| e.to_string())?;
    let max_batch: usize = args.parse_or("max-batch", 8).map_err(|e| e.to_string())?;
    if nets == 0 {
        return Err("need at least one net".into());
    }
    let chaos = args.flag("chaos");
    let fault_seed: u64 = args.parse_or("fault-seed", seed).map_err(|e| e.to_string())?;
    let boards: usize = args.parse_or("boards", 2).map_err(|e| e.to_string())?;
    let defaults = mfnn::ServeConfig::default();
    let max_retries = defaults.max_retries;
    let cfg = mfnn::ServeConfig {
        boards,
        device: args.str_or("device", "XC7S75-2"),
        max_batch,
        max_wait_cycles: args.parse_or("max-wait", 64).map_err(|e| e.to_string())?,
        queue_cap: args.parse_or("queue-cap", 1024).map_err(|e| e.to_string())?,
        faults: if chaos {
            mfnn::serve::ServeFaultPlan::survivable(fault_seed, boards, max_retries)
        } else {
            mfnn::serve::ServeFaultPlan::none()
        },
        ..defaults
    };
    let rate: u64 = args.parse_or("rate", 8).map_err(|e| e.to_string())?;
    let compiler = Compiler::new();
    let fleet = serve_sim_nets(&compiler, nets, max_batch, seed)?;
    let fixed = FixedSpec::q(10).saturating();
    let in_dims: Vec<usize> =
        fleet.iter().map(|(a, _, _)| a.spec().expect("net artifact").input_dim()).collect();
    // Plain mode submits the open-loop stream with default options —
    // bit-identical to pre-degraded-mode serving. Chaos mode rides the
    // same arrivals/rows with SLO annotations (priorities + deadlines).
    let plain = if chaos {
        Vec::new()
    } else {
        mfnn::serve::open_loop(requests, seed, rate, &in_dims, fixed)
    };
    let slo = if chaos {
        mfnn::serve::slo_open_loop(requests, seed, rate, &in_dims, fixed)
    } else {
        Vec::new()
    };

    // Run the whole workload against a fresh server; returns the report
    // plus (accepted, refused-at-submit) counts and the typed
    // post-admission drop records.
    type RunOut = (mfnn::serve::ServeReport, usize, usize, Vec<mfnn::serve::DroppedRequest>);
    let run = || -> Result<RunOut, String> {
        let mut server = mfnn::Server::open(cfg.clone()).map_err(|e| e.to_string())?;
        for (artifact, w, b) in &fleet {
            server.register(Arc::clone(artifact), w, b).map_err(|e| e.to_string())?;
        }
        let (mut accepted, mut refused) = (0usize, 0usize);
        if chaos {
            for q in &slo {
                match server.submit_with(q.at, q.net, &q.row, q.options()) {
                    Ok(_) => accepted += 1,
                    Err(mfnn::serve::ServeError::Shed { .. })
                    | Err(mfnn::serve::ServeError::DeadlineExceeded { .. }) => refused += 1,
                    Err(e) => return Err(e.to_string()),
                }
            }
        } else {
            for q in &plain {
                match server.submit_at(q.at, q.net, &q.row) {
                    Ok(_) => accepted += 1,
                    Err(mfnn::serve::ServeError::Shed { .. }) => refused += 1,
                    Err(e) => return Err(e.to_string()),
                }
            }
        }
        server.drain().map_err(|e| e.to_string())?;
        let dropped = server.take_dropped();
        Ok((server.report(), accepted, refused, dropped))
    };

    let (report, accepted, refused, dropped) = run()?;
    if args.flag("check-determinism") {
        let (again, a2, r2, d2) = run()?;
        if again.to_json() != report.to_json()
            || a2 != accepted
            || r2 != refused
            || d2 != dropped
        {
            return Err(
                "nondeterministic serving outcome: two identical-seed runs disagree".into()
            );
        }
        println!("determinism check: two identical-seed runs produced identical outcomes ✓");
    }
    print!("{}", report.render());
    let out = args.str_or("metrics-out", "serve_metrics.json");
    std::fs::write(&out, report.to_json()).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {out}");
    let completed = report.total_completed() as usize;
    if chaos {
        // Degraded-mode accounting: every admitted request terminates as
        // a completion or a typed drop — never a hang or a silent loss.
        if completed + dropped.len() != accepted {
            return Err(format!(
                "lost requests under the fault plan: accepted {accepted}, completed \
                 {completed}, dropped {} (typed)",
                dropped.len()
            ));
        }
        let shed = dropped
            .iter()
            .filter(|d| d.reason == mfnn::serve::DropReason::Shed)
            .count();
        let expired = dropped
            .iter()
            .filter(|d| d.reason == mfnn::serve::DropReason::DeadlineExceeded)
            .count();
        let budget = dropped.len() - shed - expired;
        println!(
            "chaos (fault seed {fault_seed}): {completed}/{accepted} completed, {} dropped \
             typed ({shed} shed, {expired} expired, {budget} retry-budget), {refused} refused \
             at submit — no silent losses ✓",
            dropped.len()
        );
        return Ok(());
    }
    if refused > 0 {
        return Err(format!("{refused} request(s) shed; raise --queue-cap"));
    }
    if completed != accepted {
        return Err(format!("dropped/hung requests: accepted {accepted}, completed {completed}"));
    }
    println!("{completed}/{accepted} requests completed, 0 dropped ✓");
    Ok(())
}

// --------------------------------------------------------------------- fuzz

fn cmd_fuzz(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new()
        .opt("cases", "generated cases per family (net, graph, program, fault, recovery, serve-chaos, memplan, check)", Some("64"))
        .opt("seed", "base seed (case i runs at seed + i·φ; case 0 = seed)", Some("0"))
        .opt("device", "FPGA part every level simulates", Some("XC7S75-2"))
        .opt("corpus", "replay `family seed` lines from this snapshot file", None)
        .opt("family", "restrict to one family: net|graph|program|fault|recovery|serve-chaos|memplan|check", None)
        .opt("failures-out", "write failing seeds here (corpus format)", Some("FUZZ_FAILURES.txt"))
        .opt("max-shrink", "shrink-step budget per failure", Some("100"))
        .opt("sync", "force one weight-sync policy on every cluster case: star|ring|bounded-stale[:N]", None)
        .flag("plant-divergence", "test-only hook: plant a known FastSim divergence");
    let args = parse_or_help(
        &spec,
        rest,
        "mfnn fuzz",
        "Differential-fuzz every simulator fidelity level (DESIGN.md §Testing)",
    )?;
    let part = device_arg(&args)?;
    let family = match args.get("family") {
        Some(f) => Some(
            mfnn::testkit::Family::parse(f)
                .ok_or(format!(
                    "unknown family {f:?} (net|graph|program|fault|recovery|serve-chaos|memplan|check)"
                ))?,
        ),
        None => None,
    };
    let sync_override = match args.get("sync") {
        Some(s) => Some(
            SyncPolicy::parse(s)
                .ok_or(format!("unknown sync policy {s:?} (star|ring|bounded-stale[:N])"))?,
        ),
        None => None,
    };
    let opts = mfnn::testkit::FuzzOptions {
        cases: args.parse_or("cases", 64usize).map_err(|e| e.to_string())?,
        seed: args.parse_or("seed", 0u64).map_err(|e| e.to_string())?,
        device: FpgaDevice::new(part),
        plant_divergence: args.flag("plant-divergence"),
        max_shrink_steps: args.parse_or("max-shrink", 100usize).map_err(|e| e.to_string())?,
        check_reproduction: true,
        family,
        sync_override,
    };
    let report = match args.get("corpus") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let entries = mfnn::testkit::parse_corpus(&text).map_err(|e| format!("{path}: {e}"))?;
            mfnn::testkit::replay_corpus(&entries, &opts)
        }
        None => mfnn::testkit::fuzz(&opts),
    };
    print!("{}", report.render());
    if opts.plant_divergence {
        // The planted divergence MUST be caught, shrunk, and reproduced
        // from its printed seed — this exercises the whole pipeline.
        if report.ok() {
            return Err("planted divergence was NOT caught".into());
        }
        if !report.failures.iter().any(|f| f.reproduced) {
            return Err("planted divergence did not reproduce from its printed seed".into());
        }
        println!("planted divergence caught, shrunk, and reproduced from its seed ✓");
        return Ok(());
    }
    if !report.ok() {
        let out = args.str_or("failures-out", "FUZZ_FAILURES.txt");
        std::fs::write(&out, report.failures_file()).map_err(|e| format!("{out}: {e}"))?;
        return Err(format!(
            "{} divergence(s); failing seeds written to {out}",
            report.failures.len()
        ));
    }
    Ok(())
}

// --------------------------------------------------------------------- lint

fn cmd_lint(rest: &[String]) -> Result<(), String> {
    use mfnn::analysis::{check_program, CheckLevel, CheckOptions};
    let spec = Spec::new()
        .opt("device", "FPGA part the ring/hazard passes model", Some("XC7S75-2"))
        .opt("batch", "batch size the golden nets are lowered at", Some("8"))
        .opt("level", "diagnostic level: standard (errors only) | strict (+warnings)", Some("standard"))
        .opt("bound", "assumed max |host-bound lane value| for the interval pass", None)
        .flag("json", "emit machine-readable JSON reports instead of the table")
        .pos("net", "assembly source (.nnasm); omit to sweep the golden specs", false);
    let args = parse_or_help(
        &spec,
        rest,
        "mfnn lint",
        "Static program checker: lane dataflow, fixed-point ranges, ring-FIFO \
         safety, hazard oracle (DESIGN.md §Static analysis)",
    )?;
    let part = device_arg(&args)?;
    let batch: usize = args.parse_or("batch", 8).map_err(|e| e.to_string())?;
    let level_name = args.str_or("level", "standard");
    let level = CheckLevel::parse(&level_name)
        .ok_or(format!("unknown level {level_name:?} (off|standard|strict)"))?;
    let mut copts = CheckOptions::new(level).with_device(FpgaDevice::new(part));
    if let Some(b) = args.get("bound") {
        let bound: i16 = b.parse().map_err(|e| format!("--bound {b:?}: {e}"))?;
        copts = copts.with_host_bound(bound);
    }
    let programs = match args.positional("net") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let nets = lower_file(&text).map_err(|e| e.to_string())?;
            nets.into_iter().map(|n| n.mlp.program).collect()
        }
        None => plan_programs(batch)?,
    };
    let reports: Vec<_> = programs.iter().map(|p| check_program(p, &copts)).collect();
    if args.flag("json") {
        let body: Vec<String> = reports.iter().map(|r| r.to_json()).collect();
        println!("[{}]", body.join(","));
    } else {
        let mut t = Table::new(vec![
            "program",
            "waves",
            "lane ops",
            "errors",
            "warnings",
            "ring peak/cap",
        ])
        .with_title(format!(
            "static checker on {} at level {}, batch {batch}",
            part.name,
            level.name()
        ))
        .numeric();
        for r in &reports {
            t.row(vec![
                r.program.clone(),
                r.waves.to_string(),
                r.lane_ops.to_string(),
                r.error_count().to_string(),
                r.warning_count().to_string(),
                format!("{}/{}", r.ring_peak, r.ring_capacity),
            ]);
        }
        print!("{}", t.render());
        for r in &reports {
            for d in &r.diagnostics {
                println!("  {:?} {}: {d}", d.severity(), r.program);
            }
        }
    }
    let total: usize = reports.iter().map(|r| r.diagnostics.len()).sum();
    if total > 0 {
        return Err(format!("{total} diagnostic(s) at level {}", level.name()));
    }
    if !args.flag("json") {
        println!("{} program(s) clean at level {} ✓", reports.len(), level.name());
    }
    Ok(())
}

// --------------------------------------------------------------------- plan

/// The nets the planner report sweeps: a paper-style MLP (forward and
/// training-step programs) plus the CNN and transformer-block graph
/// scenarios from `BENCH_group_perf` — lowered, planned, and compared
/// against the default packed layout.
fn plan_programs(batch: usize) -> Result<Vec<mfnn::assembler::program::Program>, String> {
    use mfnn::nn::graph::{
        lower_graph_forward, lower_mlp_forward, lower_mlp_train, Conv2dGeom, GraphSpec, INPUT,
    };
    let fixed = FixedSpec::q(10).saturating();
    let mlp = MlpSpec::from_dims(
        "mlp_16_32_32_10",
        &[16, 32, 32, 10],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .map_err(|e| e.to_string())?;

    let gfixed = FixedSpec::q(9).saturating();
    let geom = Conv2dGeom { in_h: 8, in_w: 8, in_c: 1, out_c: 8, kh: 3, kw: 3, stride: 1 };
    let mut conv = GraphSpec::new("cnn_8x8", 64, gfixed, LutParams::training(gfixed));
    let c = conv.conv2d(INPUT, geom);
    let ca = conv.activation(c, ActKind::Relu);
    conv.linear(ca, 10);

    let (seq, d) = (8, 8);
    let mut xfmr =
        GraphSpec::new("transformer_block", seq * d, gfixed, LutParams::training(gfixed));
    let att = xfmr.attention(INPUT, seq, d);
    let r1 = xfmr.add(att, INPUT);
    let n1 = xfmr.normalization(r1, d);
    let f1 = xfmr.linear(n1, seq * d);
    let fa = xfmr.activation(f1, ActKind::Relu);
    let f2 = xfmr.linear(fa, seq * d);
    let r2 = xfmr.add(f2, n1);
    xfmr.normalization(r2, d);

    Ok(vec![
        lower_mlp_forward(&mlp, batch).map_err(|e| e.to_string())?.program,
        lower_mlp_train(&mlp, batch, 1.0 / 128.0).map_err(|e| e.to_string())?.program,
        lower_graph_forward(&conv, batch).map_err(|e| e.to_string())?.program,
        lower_graph_forward(&xfmr, batch).map_err(|e| e.to_string())?.program,
    ])
}

fn cmd_plan(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new()
        .opt("device", "board the fit check targets", Some("XC7S75-2"))
        .opt("batch", "batch size the nets are lowered at", Some("8"))
        .opt("out", "report path for --report", Some("PLAN_REPORT.md"))
        .flag("report", "also write the table as a Markdown report (CI artifact)");
    let args = parse_or_help(
        &spec,
        rest,
        "mfnn plan",
        "Static memory-planner report: packed vs planned peak lanes/BRAM per net",
    )?;
    let part = device_arg(&args)?;
    let batch: usize = args.parse_or("batch", 8).map_err(|e| e.to_string())?;
    let capacity = MemPlan::board_lanes(part);
    let mut t = Table::new(vec![
        "net",
        "steps",
        "packed lanes",
        "planned lanes",
        "saved",
        "packed BRAM18",
        "planned BRAM18",
        "fit",
    ])
    .with_title(format!(
        "static memory planner on {} ({} RAMB18 = {} lanes), batch {batch}",
        part.name, part.bram18, capacity
    ))
    .numeric();
    let mut rows = Vec::new();
    for p in plan_programs(batch)? {
        let mp = MemPlan::build(&p);
        let fit = match mp.require_fit(part.name, capacity) {
            Ok(()) => "✓".to_string(),
            Err(mfnn::hw::PlanError::ExceedsBoard { split_step, .. }) => {
                format!("split@{split_step}")
            }
        };
        let cells = vec![
            mp.name().to_string(),
            mp.steps().to_string(),
            mp.packed_lanes().to_string(),
            mp.peak_lanes().to_string(),
            mp.saved_lanes().to_string(),
            mp.packed_bram().to_string(),
            mp.peak_bram().to_string(),
            fit,
        ];
        t.row(cells.clone());
        rows.push(cells);
    }
    print!("{}", t.render());
    if args.flag("report") {
        let out = args.str_or("out", "PLAN_REPORT.md");
        let mut md = String::new();
        md.push_str("# Static memory-planner report\n\n");
        md.push_str(&format!(
            "Board `{}` — {} RAMB18 blocks = {} 16-bit lanes; nets lowered at batch \
             {batch}.\n\n",
            part.name, part.bram18, capacity
        ));
        md.push_str(
            "`planned` is the lane-reuse layout (`hw::memplan`); `packed` is the default \
             whole-program layout. Planned execution is bit-identical to packed — enforced \
             by the `memplan` fuzz family and the planner property tests.\n\n",
        );
        md.push_str(
            "| net | steps | packed lanes | planned lanes | saved | packed BRAM18 | \
             planned BRAM18 | fit |\n|---|---|---|---|---|---|---|---|\n",
        );
        for cells in &rows {
            md.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        std::fs::write(&out, md).map_err(|e| format!("{out}: {e}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

// ------------------------------------------------------------------- tables

fn cmd_tables(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new().opt("which", "t2|t3|t8|alloc|perf|all", Some("all"));
    let args = parse_or_help(&spec, rest, "mfnn tables", "Regenerate the paper's tables")?;
    let which = args.str_or("which", "all");
    let all = which == "all";
    if all || which == "t2" {
        let mut t = Table::new(vec!["Instruction", "Op code", "Description"])
            .with_title("Table 2: instruction set architecture");
        for op in mfnn::isa::Opcode::ALL {
            t.row(vec![
                op.mnemonic().to_string(),
                format!("{:03b}", op.bits()),
                op.description().to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    if all || which == "t3" {
        use mfnn::assembler::resource::{ACTPRO_PG_USAGE, MVM_PG_USAGE};
        let mut t = Table::new(vec!["Component", "LUTs", "FFs", "RAMB18Ks", "DSPs"])
            .with_title("Table 3: processor group resource usages")
            .numeric();
        for (n, u) in [("MVM_PG", MVM_PG_USAGE), ("ACTPRO_PG", ACTPRO_PG_USAGE)] {
            t.row(vec![
                n.to_string(),
                u.luts.to_string(),
                u.ffs.to_string(),
                u.bram18.to_string(),
                u.dsps.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    if all || which == "t8" {
        let mut t = Table::new(vec![
            "FPGA",
            "IO pins",
            "DDR channels",
            "DDR Bus Clock (MHz)",
            "Cost (CAD)",
            "DDR/Cost (Mb/s/CAD)",
        ])
        .with_title("Table 8: performance/cost evaluation of FPGAs (Eqns 10-11)")
        .numeric();
        for p in &CATALOG {
            t.row(vec![
                p.name.to_string(),
                p.io_pins.to_string(),
                p.ddr_channels.to_string(),
                format!("{}", p.ddr_clock_mhz),
                format!("{}", p.cost_cad),
                f(p.perf_cost_paper(), 2),
            ]);
        }
        print!("{}", t.render());
        let best = CATALOG
            .iter()
            .max_by(|a, b| a.perf_cost().partial_cmp(&b.perf_cost()).unwrap())
            .unwrap();
        println!("selected (argmax F): {}\n", best.name);
    }
    if all || which == "alloc" {
        let mut t = Table::new(vec!["FPGA", "N_MVM_PG (Eqn 3)", "N_ACTPRO_PG (Eqn 4)"])
            .with_title("Eqns 3-4: processor-group allocation per part")
            .numeric();
        for p in &CATALOG {
            let d = FpgaDevice::new(p);
            t.row(vec![
                p.name.to_string(),
                d.mvm_groups.to_string(),
                d.actpro_groups.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    if all || which == "perf" {
        let m = PerfModel::paper();
        let mut t = Table::new(vec!["op", "N_I", "T_RUN", "T_all", "E", "P (elem/s)", "R (Mb/s)"])
            .with_title("Sec 4.1 worked examples (Eqns 5-9), N_I = 1024")
            .numeric();
        for (name, class) in [
            ("vector addition", OpClass::Elementwise),
            ("vector dot product", OpClass::Reduction),
            ("activation function", OpClass::Activation),
        ] {
            let g = m.group_perf(class, 1024);
            t.row(vec![
                name.to_string(),
                "1024".to_string(),
                g.t_run.to_string(),
                g.t_all.to_string(),
                f(g.e_paper(), 3),
                format!("{:.3e}", g.p),
                f(g.r, 0),
            ]);
        }
        print!("{}", t.render());
    }
    Ok(())
}

// ------------------------------------------------------------------- traces

fn cmd_traces(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new();
    parse_or_help(&spec, rest, "mfnn traces", "Print the paper's timing diagrams")?;
    print!("{}", mfnn::hw::trace_figures::all_figures());
    Ok(())
}

// ------------------------------------------------------------------- golden

#[cfg(not(feature = "xla"))]
fn cmd_golden(_rest: &[String]) -> Result<(), String> {
    Err("the `golden` command needs the PJRT runtime; rebuild with `--features xla` \
         (see DESIGN.md §Runtime)"
        .into())
}

#[cfg(feature = "xla")]
fn cmd_golden(rest: &[String]) -> Result<(), String> {
    let spec = Spec::new().opt("dir", "artifacts directory", None);
    let args = parse_or_help(&spec, rest, "mfnn golden", "Cross-check sim vs JAX artifacts")?;
    let dir =
        args.get("dir").map(std::path::PathBuf::from).unwrap_or_else(Runtime::default_dir);
    let g = GoldenModel::open(&dir).map_err(|e| e.to_string())?;
    println!(
        "golden model: dims {:?}, batch {}, Q{}.{}",
        g.spec.layers.iter().map(|l| l.inputs).chain([g.spec.output_dim()]).collect::<Vec<_>>(),
        g.batch,
        16 - g.spec.fixed.frac_bits,
        g.spec.fixed.frac_bits
    );
    let h =
        mfnn::nn::graph::lower_mlp_train(&g.spec, g.batch, g.lr).map_err(|e| e.to_string())?;
    let mut r = Rng::new(0xC0FFEE);
    let fsp = g.spec.fixed;
    let rand = |n: usize, amp: f64, r: &mut Rng| -> Vec<i16> {
        (0..n).map(|_| fsp.from_f64((r.gen_f64() - 0.5) * amp)).collect()
    };
    let ws: Vec<Vec<i16>> =
        g.spec.layers.iter().map(|l| rand(l.inputs * l.outputs, 1.2, &mut r)).collect();
    let bs: Vec<Vec<i16>> = g.spec.layers.iter().map(|l| rand(l.outputs, 0.4, &mut r)).collect();
    let x = rand(g.batch * g.spec.input_dim(), 2.0, &mut r);
    let y = rand(g.batch * g.spec.output_dim(), 1.0, &mut r);
    let mut m = mfnn::hw::MatrixMachine::new(FpgaDevice::selected(), &h.program)
        .map_err(|e| e.to_string())?;
    m.bind_named("x", &x).map_err(|e| e.to_string())?;
    m.bind_named("y", &y).map_err(|e| e.to_string())?;
    for l in 0..g.spec.layers.len() {
        m.bind_named(&format!("w{l}"), &ws[l]).map_err(|e| e.to_string())?;
        m.bind_named(&format!("b{l}"), &bs[l]).map_err(|e| e.to_string())?;
    }
    m.execute();
    let step = g.train_step(&x, &y, &ws, &bs).map_err(|e| e.to_string())?;
    let last = g.spec.layers.len() - 1;
    let sim_out = m.read_named(&format!("o{last}")).unwrap();
    if sim_out != &step.out[..] {
        return Err("FORWARD OUTPUTS DIVERGE".into());
    }
    for l in 0..g.spec.layers.len() {
        if m.read_named(&format!("w{l}")).unwrap() != &step.weights[l][..] {
            return Err(format!("LAYER {l} WEIGHTS DIVERGE"));
        }
    }
    println!("sim == golden: forward outputs, loss lane, and updated weights are bit-exact ✓");
    Ok(())
}
