//! Compiled, immutable artifacts and their typed tensor handles.
//!
//! An [`Artifact`] is the output of [`super::Compiler`]: a validated
//! vector [`Program`] (two for trainable nets — the training-step
//! program plus the forward/testing program), the net's reconstructed
//! identity (a [`NetSpec`]: an [`MlpSpec`] layer list or an
//! operator-graph [`GraphSpec`]), the tensor [`SymbolTable`] resolved
//! once at compile
//! time, and a per-device cache of compiled [`ExecPlan`]s. Artifacts are
//! shared (`Arc`) between the compiler cache and any number of open
//! [`super::Session`]s; opening a second session on the same
//! `(net, device)` pair reuses the cached plan instead of rebuilding it.

use super::error::Error;
use crate::analysis::CheckReport;
use crate::assembler::program::{BufId, BufKind, Program, SymbolTable};
use crate::fixed::FixedSpec;
use crate::hw::machine::MachineError;
use crate::hw::{ExecPlan, FpgaDevice, MatrixMachine};
use crate::nn::graph::{lower_graph_forward, lower_mlp_forward, GraphSpec};
use crate::nn::lowering::{LowerError, LoweredMlp};
use crate::nn::trainer::TrainConfig;
use crate::nn::MlpSpec;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex};

/// First-class net identity of a compiled artifact: either the fixed
/// MLP topology or a general operator graph. Both lower onto the same
/// MVM/ActPro program shape (`LoweredMlp` handles), so everything
/// downstream of compilation — sessions, the forward batch ladder, the
/// serving runtime — treats the two uniformly through this enum's
/// accessors.
#[derive(Debug, Clone)]
pub enum NetSpec {
    /// A classic layer-list MLP ([`MlpSpec`]).
    Mlp(MlpSpec),
    /// An operator graph ([`GraphSpec`]): CNNs, residual/gated blocks,
    /// transformer blocks, …
    Graph(GraphSpec),
}

impl NetSpec {
    /// Network name.
    pub fn name(&self) -> &str {
        match self {
            NetSpec::Mlp(s) => &s.name,
            NetSpec::Graph(g) => &g.name,
        }
    }

    /// Input dimension (columns of one sample row).
    pub fn input_dim(&self) -> usize {
        match self {
            NetSpec::Mlp(s) => s.input_dim(),
            NetSpec::Graph(g) => g.input_dim(),
        }
    }

    /// Output dimension.
    pub fn output_dim(&self) -> usize {
        match self {
            NetSpec::Mlp(s) => s.output_dim(),
            NetSpec::Graph(g) => g.output_dim(),
        }
    }

    /// Datapath fixed-point format.
    pub fn fixed(&self) -> FixedSpec {
        match self {
            NetSpec::Mlp(s) => s.fixed,
            NetSpec::Graph(g) => g.fixed,
        }
    }

    /// The MLP spec, when this net is one.
    pub fn as_mlp(&self) -> Option<&MlpSpec> {
        match self {
            NetSpec::Mlp(s) => Some(s),
            NetSpec::Graph(_) => None,
        }
    }

    /// The operator graph, when this net is one.
    pub fn as_graph(&self) -> Option<&GraphSpec> {
        match self {
            NetSpec::Mlp(_) => None,
            NetSpec::Graph(g) => Some(g),
        }
    }

    /// `(rows, cols)` of every `(weights, bias)` parameter pair, in
    /// lowered-buffer order — the shape contract serving registration
    /// validates caller-supplied parameters against.
    pub fn param_shapes(&self) -> Vec<(usize, usize)> {
        match self {
            NetSpec::Mlp(s) => s.layers.iter().map(|l| (l.inputs, l.outputs)).collect(),
            NetSpec::Graph(g) => g
                .param_decls()
                .expect("compiled artifacts hold validated graphs")
                .iter()
                .map(|d| (d.rows, d.cols))
                .collect(),
        }
    }

    /// Lower the forward program at `rows` (the batch-ladder bucket
    /// lowering).
    pub(crate) fn lower_forward(&self, rows: usize) -> Result<LoweredMlp, LowerError> {
        match self {
            NetSpec::Mlp(s) => lower_mlp_forward(s, rows),
            NetSpec::Graph(g) => lower_graph_forward(g, rows),
        }
    }
}

/// Network-shaped payload: spec + lowered programs.
pub(crate) struct NetInfo {
    /// Reconstructed network identity.
    pub spec: NetSpec,
    /// Batch size both programs were lowered for.
    pub batch: usize,
    /// Learning rate baked into the training program (`None` ⇒ the
    /// artifact is inference-only).
    pub lr: Option<f64>,
    /// Forward/testing program with its buffer handles.
    pub forward: LoweredMlp,
    /// Training-step program (present when `lr` is set).
    pub train: Option<LoweredMlp>,
    /// Compile every [`ExecPlan`] with the static memory planner's
    /// lane-reuse layout (`CompileOptions::memory_plan`). Bit-exact with
    /// the packed layout — see DESIGN.md §Memory planner.
    pub memory_plan: bool,
}

/// What an artifact wraps.
pub(crate) enum Payload {
    /// A compiled network (spec known; all session verbs available).
    Net(NetInfo),
    /// A raw validated vector program (tensor handles + `step()` only).
    Raw(Program),
}

/// Compiled plans for one device.
#[derive(Clone)]
pub(crate) struct DevicePlans {
    /// Plan of the primary program (train for trainable nets).
    pub primary: Arc<ExecPlan>,
    /// Plan of the forward program (same `Arc` when the primary program
    /// *is* the forward program). Comes from the forward batch ladder
    /// ([`Artifact::forward_variant`]) so sessions and the serving
    /// runtime share one compiled plan per `(net, batch, device)`.
    pub forward: Arc<ExecPlan>,
}

/// One batch-size bucket of a net's forward ladder: the forward program
/// lowered at that batch plus its per-device compiled-plan cache. The
/// serving runtime opens one engine (plan + private state) per
/// `(board, net, bucket)`; the plan itself is compiled exactly once per
/// `(net, bucket, device)` no matter how many boards or servers use it.
pub struct ForwardVariant {
    lowered: LoweredMlp,
    /// Build plans with the memory planner's lane-reuse layout
    /// (inherited from the artifact's compile options).
    planned: bool,
    plans: Mutex<HashMap<String, Arc<ExecPlan>>>,
}

impl std::fmt::Debug for ForwardVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ForwardVariant").field("batch", &self.lowered.batch).finish()
    }
}

impl ForwardVariant {
    /// Batch rows this variant's forward program was lowered for.
    pub fn batch(&self) -> usize {
        self.lowered.batch
    }

    /// The lowered forward program with its buffer handles
    /// (`x`/`out`/`weights`/`biases` ids).
    pub fn lowered(&self) -> &LoweredMlp {
        &self.lowered
    }

    /// The compiled plan for `device`, building and caching it on first
    /// use.
    pub fn plan_for(&self, device: &FpgaDevice) -> Arc<ExecPlan> {
        let mut map = self.plans.lock().expect("forward plan cache poisoned");
        Arc::clone(map.entry(device.part.name.to_string()).or_insert_with(|| {
            Arc::new(if self.planned {
                ExecPlan::new_planned(&self.lowered.program, device)
            } else {
                ExecPlan::new(&self.lowered.program, device)
            })
        }))
    }

    /// A [`MatrixMachine`] on this variant's cached plan (fresh private
    /// state; parameters unbound).
    pub fn machine(&self, device: FpgaDevice) -> Result<MatrixMachine, MachineError> {
        MatrixMachine::with_plan(device, &self.lowered.program, self.plan_for(&device))
    }
}

/// An immutable compiled artifact: validated program(s) + symbol table +
/// per-device execution plans.
///
/// ```
/// use mfnn::session::{CompileOptions, Compiler};
/// use mfnn::fixed::FixedSpec;
/// use mfnn::nn::lut::ActKind;
/// use mfnn::nn::mlp::{LutParams, MlpSpec};
///
/// let fixed = FixedSpec::q(10).saturating();
/// let spec = MlpSpec::from_dims(
///     "tiny", &[2, 4, 2], ActKind::Relu, ActKind::Identity,
///     fixed, LutParams::training(fixed),
/// ).unwrap();
/// let compiler = Compiler::new();
/// let artifact = compiler.compile_spec(&spec, &CompileOptions::inference(4)).unwrap();
/// // Typed handles are resolved once, at compile time of the artifact:
/// let w0 = artifact.tensor("w0").unwrap();
/// assert_eq!((w0.rows(), w0.cols()), (2, 4));
/// // Misses come back with a suggestion, not a bare error:
/// let err = artifact.tensor("w00").unwrap_err().to_string();
/// assert!(err.contains("did you mean \"w0\""), "{err}");
/// ```
pub struct Artifact {
    fingerprint: u64,
    payload: Payload,
    symbols: SymbolTable,
    plans: Mutex<HashMap<String, DevicePlans>>,
    /// Forward batch ladder: one lowered forward program (+ per-device
    /// plan cache) per batch size ever requested. The compiled batch's
    /// variant wraps the artifact's own forward program; other buckets
    /// lower lazily on first use.
    forward_variants: Mutex<HashMap<usize, Arc<ForwardVariant>>>,
    /// Static-checker reports, one per compiled program (forward, then
    /// train), when the artifact was compiled with
    /// `CompileOptions::with_checks` at a level above `Off`. Empty
    /// otherwise (including `compile_asm`/`compile_program` artifacts).
    checks: Vec<CheckReport>,
}

impl std::fmt::Debug for Artifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Artifact")
            .field("name", &self.name())
            .field("trainable", &self.trainable())
            .field("tensors", &self.symbols.len())
            .finish()
    }
}

impl Artifact {
    pub(crate) fn new(key: String, payload: Payload) -> Artifact {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        let fingerprint = h.finish();
        let symbols = match &payload {
            Payload::Net(n) => n
                .train
                .as_ref()
                .map(|t| t.program.symbols())
                .unwrap_or_else(|| n.forward.program.symbols()),
            Payload::Raw(p) => p.symbols(),
        };
        Artifact {
            fingerprint,
            payload,
            symbols,
            plans: Mutex::new(HashMap::new()),
            forward_variants: Mutex::new(HashMap::new()),
            checks: Vec::new(),
        }
    }

    /// Attach the static-checker reports gathered at compile time
    /// (compiler-internal; called before the artifact is shared).
    pub(crate) fn with_check_reports(mut self, checks: Vec<CheckReport>) -> Artifact {
        self.checks = checks;
        self
    }

    /// The static-checker reports attached at compile time — one per
    /// compiled program (forward first, then the training program), in
    /// the order the checker ran. Empty when compiled at
    /// [`crate::analysis::CheckLevel::Off`] (the default).
    pub fn check_reports(&self) -> &[CheckReport] {
        &self.checks
    }

    /// Fingerprint used to tag [`TensorHandle`]s.
    pub(crate) fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub(crate) fn net(&self) -> Option<&NetInfo> {
        match &self.payload {
            Payload::Net(n) => Some(n),
            Payload::Raw(_) => None,
        }
    }

    /// The primary program (training-step program for trainable nets,
    /// the forward program otherwise, the raw program for
    /// [`super::Compiler::compile_program`] artifacts).
    pub fn program(&self) -> &Program {
        match &self.payload {
            Payload::Net(n) => {
                n.train.as_ref().map(|t| &t.program).unwrap_or(&n.forward.program)
            }
            Payload::Raw(p) => p,
        }
    }

    /// Artifact name (the net name for compiled networks, the program
    /// name for raw-program artifacts).
    pub fn name(&self) -> &str {
        match &self.payload {
            Payload::Net(n) => n.spec.name(),
            Payload::Raw(p) => &p.name,
        }
    }

    /// The reconstructed MLP spec (`None` for raw-program artifacts
    /// **and** for operator-graph nets — see [`Artifact::net_spec`] for
    /// the uniform identity).
    pub fn spec(&self) -> Option<&MlpSpec> {
        self.net().and_then(|n| n.spec.as_mlp())
    }

    /// The net's first-class identity — MLP or operator graph (`None`
    /// for raw-program artifacts).
    pub fn net_spec(&self) -> Option<&NetSpec> {
        self.net().map(|n| &n.spec)
    }

    /// The operator graph, when this artifact compiled one.
    pub fn graph_spec(&self) -> Option<&GraphSpec> {
        self.net().and_then(|n| n.spec.as_graph())
    }

    /// Batch size the net was compiled for (`None` for raw programs).
    pub fn batch(&self) -> Option<usize> {
        self.net().map(|n| n.batch)
    }

    /// Learning rate baked into the training program, when trainable.
    pub fn lr(&self) -> Option<f64> {
        self.net().and_then(|n| n.lr)
    }

    /// True when the artifact carries a training-step program.
    pub fn trainable(&self) -> bool {
        self.net().is_some_and(|n| n.train.is_some())
    }

    /// Datapath fixed-point format.
    pub fn fixed(&self) -> FixedSpec {
        self.program().fixed
    }

    /// The tensor symbol table (names resolved once at compile time).
    pub fn symbols(&self) -> &SymbolTable {
        &self.symbols
    }

    fn handle_for(&self, id: BufId) -> TensorHandle {
        let decl = &self.program().buffers[id];
        TensorHandle {
            artifact: self.fingerprint,
            name: decl.name.clone(),
            id,
            rows: decl.rows,
            cols: decl.cols,
            kind: decl.kind,
            fixed: self.program().fixed,
        }
    }

    /// Resolve a tensor name into a typed handle (shape and fixed format
    /// checked here, once — not at every bind).
    pub fn tensor(&self, name: &str) -> Result<TensorHandle, Error> {
        match self.symbols.resolve(name) {
            Some(id) => Ok(self.handle_for(id)),
            None => Err(Error::UnknownTensor {
                artifact: self.name().to_string(),
                name: name.to_string(),
                hint: self.symbols.hint(name),
            }),
        }
    }

    /// Handles for every declared tensor, in declaration order.
    pub fn tensors(&self) -> Vec<TensorHandle> {
        (0..self.program().buffers.len()).map(|id| self.handle_for(id)).collect()
    }

    /// The compiled primary-program plan for `device`, building and
    /// caching it on first use — the second `open` of the same
    /// `(net, device)` pair returns the same `Arc` without rebuilding.
    pub fn plan_for(&self, device: &FpgaDevice) -> Arc<ExecPlan> {
        self.plans_for(device).primary
    }

    pub(crate) fn plans_for(&self, device: &FpgaDevice) -> DevicePlans {
        if let Some(hit) =
            self.plans.lock().expect("plan cache poisoned").get(device.part.name)
        {
            return hit.clone();
        }
        let plans = match &self.payload {
            Payload::Net(n) => {
                // The forward plan comes from the batch ladder so every
                // consumer of `(net, compiled batch, device)` — sessions,
                // evaluation chunks, the serving runtime — shares one
                // compiled plan.
                let forward = self
                    .forward_variant(n.batch)
                    .expect("compiled batch is always a valid forward variant")
                    .plan_for(device);
                let primary = if n.train.is_some() {
                    Arc::new(if n.memory_plan {
                        ExecPlan::new_planned(self.program(), device)
                    } else {
                        ExecPlan::new(self.program(), device)
                    })
                } else {
                    Arc::clone(&forward)
                };
                DevicePlans { primary, forward }
            }
            Payload::Raw(p) => {
                let primary = Arc::new(ExecPlan::new(p, device));
                DevicePlans { primary: Arc::clone(&primary), forward: primary }
            }
        };
        self.plans
            .lock()
            .expect("plan cache poisoned")
            .entry(device.part.name.to_string())
            .or_insert(plans)
            .clone()
    }

    /// The forward-ladder variant for a `rows`-row micro-batch: the
    /// forward program lowered at exactly `rows` (cached per batch size)
    /// with its per-device compiled-plan cache. `rows` equal to the
    /// compiled batch reuses the artifact's own forward program; any
    /// other bucket lowers lazily on first request. Raw-program
    /// artifacts have no forward structure and are rejected.
    pub fn forward_variant(&self, rows: usize) -> Result<Arc<ForwardVariant>, Error> {
        let net = self.net().ok_or_else(|| Error::Unsupported {
            verb: "forward_variant",
            why: "raw-program artifacts have no network structure".into(),
        })?;
        if let Some(hit) =
            self.forward_variants.lock().expect("forward ladder poisoned").get(&rows)
        {
            return Ok(Arc::clone(hit));
        }
        let lowered = if rows == net.batch {
            net.forward.clone()
        } else {
            net.spec.lower_forward(rows)?
        };
        let variant = Arc::new(ForwardVariant {
            lowered,
            planned: net.memory_plan,
            plans: Mutex::new(HashMap::new()),
        });
        Ok(Arc::clone(
            self.forward_variants
                .lock()
                .expect("forward ladder poisoned")
                .entry(rows)
                .or_insert(variant),
        ))
    }

    /// Validate a `TrainConfig` against what this artifact was compiled
    /// for (compile-once contract: batch and lr are baked into the
    /// training program).
    pub(crate) fn check_train_cfg(&self, cfg: &TrainConfig) -> Result<(), Error> {
        let net = self.net().ok_or_else(|| Error::Unsupported {
            verb: "train",
            why: "raw-program artifacts have no network structure".into(),
        })?;
        let lr = net.lr.ok_or_else(|| Error::Unsupported {
            verb: "train",
            why: format!(
                "artifact {:?} was compiled for inference only; recompile \
                 with CompileOptions::training",
                self.name()
            ),
        })?;
        if cfg.batch != net.batch {
            return Err(Error::ConfigMismatch {
                what: "batch",
                compiled: net.batch.to_string(),
                requested: cfg.batch.to_string(),
            });
        }
        if cfg.lr != lr {
            return Err(Error::ConfigMismatch {
                what: "lr",
                compiled: lr.to_string(),
                requested: cfg.lr.to_string(),
            });
        }
        Ok(())
    }
}

/// A typed tensor handle: name resolved to a buffer id once, shape and
/// fixed format carried along — [`super::Session::write`] checks lengths
/// against the handle instead of re-scanning buffer tables per bind.
#[derive(Debug, Clone)]
pub struct TensorHandle {
    artifact: u64,
    name: String,
    id: BufId,
    rows: usize,
    cols: usize,
    kind: BufKind,
    fixed: FixedSpec,
}

impl TensorHandle {
    /// Fingerprint of the artifact this handle belongs to.
    pub(crate) fn artifact(&self) -> u64 {
        self.artifact
    }

    /// Resolved buffer id.
    pub(crate) fn id(&self) -> BufId {
        self.id
    }

    /// Tensor name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Declared rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Declared columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total lanes (`rows × cols`).
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    /// True for degenerate empty tensors (never in checked programs).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Buffer role.
    pub fn kind(&self) -> BufKind {
        self.kind
    }

    /// Fixed-point format of the lanes.
    pub fn fixed(&self) -> FixedSpec {
        self.fixed
    }

    /// True when this tensor holds trainable parameters.
    pub fn is_param(&self) -> bool {
        matches!(self.kind, BufKind::Weight | BufKind::Bias)
    }
}
