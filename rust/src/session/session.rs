//! The [`Session`]: an opened artifact on a target, with typed tensor
//! I/O and the three uniform verbs.
//!
//! * [`Session::infer`] — one forward batch on the bound parameters.
//! * [`Session::train`] — SGD training; on a board target the embedded
//!   [`Trainer`] engine runs locally, on a cluster target the job is
//!   dispatched through [`crate::cluster::leader::execute`] (divided /
//!   1:1 per the paper's §2) and the averaged weights are adopted back
//!   into the session.
//! * [`Session::evaluate`] — classification accuracy over a dataset,
//!   chunked by [`dataset::chunk_ranges`] (the same helper the trainer
//!   uses — one chunking rule for every path).
//!
//! Plus the raw escape hatch [`Session::step`] / [`Session::write`] /
//! [`Session::read`] for programs that need exact control of every
//! tensor (golden-model cross-checks, raw-program artifacts).

use super::artifact::{Artifact, ForwardVariant, NetSpec, TensorHandle};
use super::error::Error;
use crate::cluster::checkpoint::{RunIdentity, TrainCheckpoint};
use crate::cluster::cost::SyncPolicy;
use crate::cluster::leader::{self, ClusterConfig, ClusterReport, Job, JobResume};
use crate::hw::{FpgaDevice, MatrixMachine, RunStats};
use crate::nn::dataset::{self, Dataset};
use crate::nn::graph::GraphTrainer;
use crate::nn::trainer::{LossPoint, TrainConfig, Trainer};
use crate::serve;
use std::sync::Arc;

/// Where a session runs.
#[derive(Debug, Clone)]
pub enum Target {
    /// One simulated FPGA board.
    Board(FpgaDevice),
    /// A multi-FPGA cluster (training is dispatched to the cluster
    /// runtime; inference/evaluation run on one board of the cluster's
    /// part).
    Cluster(ClusterConfig),
}

/// Result of [`Session::infer`].
#[derive(Debug, Clone)]
pub struct Inference {
    /// Quantised `batch × out_dim` output activations.
    pub output: Vec<i16>,
    /// Machine statistics of the pass.
    pub stats: RunStats,
}

/// Result of [`Session::evaluate`].
#[derive(Debug, Clone, Copy)]
pub struct Evaluation {
    /// Classification accuracy in `[0, 1]`.
    pub accuracy: f64,
    /// Aggregated machine statistics.
    pub stats: RunStats,
}

/// Result of [`Session::train`].
#[derive(Debug, Clone)]
pub struct TrainSummary {
    /// Loss curve (replica 0's view for divided cluster jobs).
    pub curve: Vec<LossPoint>,
    /// Aggregated machine statistics.
    pub stats: RunStats,
    /// Simulated seconds (compute + bus for cluster targets).
    pub sim_seconds: f64,
    /// Steps executed (per replica).
    pub steps: usize,
    /// Boards the job ran on (`[0]` for a board target).
    pub boards: Vec<usize>,
    /// Weight-averaging rounds (0 for board targets).
    pub sync_rounds: u64,
}

/// One net's entry in [`Session::train_many`].
pub struct NetJob {
    /// Compiled trainable artifact.
    pub artifact: Arc<Artifact>,
    /// Training configuration (must match the artifact's compiled
    /// batch/lr).
    pub cfg: TrainConfig,
    /// Training split.
    pub train: Arc<Dataset>,
    /// Test split (evaluated after training).
    pub test: Arc<Dataset>,
    /// Resume this job bit-exactly from a [`TrainCheckpoint`] (validated
    /// against the job's identity) instead of starting from scratch —
    /// what `mfnn train --resume` loads per job.
    pub resume: Option<TrainCheckpoint>,
}

/// Checkpoint/resume options for [`Session::train_with`].
#[derive(Debug, Clone, Default)]
pub struct TrainOptions {
    /// Capture a deterministic [`TrainCheckpoint`] every this many steps
    /// (0 = none). On a board target this also chunks the training loop
    /// at the same cadence; on a cluster target it sets the run's
    /// [`crate::cluster::RecoveryPolicy::checkpoint_every`] (divided
    /// jobs snapshot at weight-sync boundaries).
    pub checkpoint_every: usize,
    /// Resume from this snapshot: validated against the run's identity
    /// (net, seed, batch, steps). The continuation always reproduces
    /// the uninterrupted run's **weights** bit-exactly; the loss curve
    /// and simulated-seconds accounting are additionally bit-exact when
    /// the resumed run uses the **same** [`TrainOptions::checkpoint_every`]
    /// as the original (chunk boundaries are observable in the curve's
    /// logging cadence, so a different cadence logs different steps).
    pub resume: Option<TrainCheckpoint>,
}

impl TrainOptions {
    /// Checkpoint every `steps` steps, no resume.
    pub fn checkpoint_every(steps: usize) -> TrainOptions {
        TrainOptions { checkpoint_every: steps, resume: None }
    }

    /// Resume from `ck` with checkpointing off. Weights are bit-exact
    /// regardless; for a bit-exact loss curve too, set
    /// [`TrainOptions::checkpoint_every`] to the original run's cadence
    /// (see [`TrainOptions::resume`] (field) docs).
    pub fn resume(ck: TrainCheckpoint) -> TrainOptions {
        TrainOptions { checkpoint_every: 0, resume: Some(ck) }
    }
}

enum Engine {
    /// Trainable MLP artifact: the [`Trainer`] engine owns both
    /// machines; its training machine is the session's primary machine.
    Trainable(Box<Trainer>),
    /// Trainable operator-graph artifact: the [`GraphTrainer`] engine —
    /// same machine layout, parameters keyed by the graph's
    /// `param_decls` order instead of per-layer.
    GraphTrainable(Box<GraphTrainer>),
    /// Inference-only or raw artifact: one machine on the primary plan.
    Forward(Box<MatrixMachine>),
}

/// An opened artifact on a target.
///
/// ```
/// use mfnn::session::{CompileOptions, Compiler, Session, Target};
/// use mfnn::hw::FpgaDevice;
/// use mfnn::nn::dataset;
/// use mfnn::nn::lut::ActKind;
/// use mfnn::nn::mlp::{LutParams, MlpSpec};
/// use mfnn::nn::trainer::TrainConfig;
/// use mfnn::fixed::FixedSpec;
///
/// let fixed = FixedSpec::q(10).saturating();
/// let spec = MlpSpec::from_dims(
///     "xor", &[2, 8, 2], ActKind::Relu, ActKind::Identity,
///     fixed, LutParams::training(fixed),
/// ).unwrap();
/// let compiler = Compiler::new();
/// let artifact = compiler
///     .compile_spec(&spec, &CompileOptions::training(8, 1.0 / 128.0))
///     .unwrap();
/// let mut session =
///     Session::open(artifact, Target::Board(FpgaDevice::selected())).unwrap();
/// let ds = dataset::xor(64, 7);
/// let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 20, seed: 1, log_every: 5 };
/// let report = session.train(&ds, &cfg).unwrap();
/// assert_eq!(report.steps, 20);
/// let eval = session.evaluate(&ds).unwrap();
/// assert!(eval.accuracy >= 0.0 && eval.accuracy <= 1.0);
/// let out = session.infer(&ds.encode_rows(0..8, fixed)).unwrap();
/// assert_eq!(out.output.len(), 8 * 2);
/// ```
pub struct Session {
    artifact: Arc<Artifact>,
    device: FpgaDevice,
    cluster: Option<ClusterConfig>,
    engine: Engine,
    /// Set once parameters exist on-device (handle writes to weight/bias
    /// tensors, explicit init, or a completed train): `train` then
    /// continues from them instead of re-initialising from the seed.
    weights_ready: bool,
    /// Set once the batch-sampling RNG has been seeded from a train
    /// call's `cfg.seed`; later train calls continue the stream.
    sampler_seeded: bool,
    /// Cached right-sized engine for the partial evaluation chunk of
    /// inference-only sessions (`(rows, variant, machine)`): the plan
    /// comes from the artifact's forward ladder; the machine's state
    /// (including its resident LUT) persists across evaluate calls, so
    /// repeated evaluations charge the same cycles the trainer engine's
    /// cached variants do. Parameters are refreshed from the session
    /// machine on every pass (they may have been rebound through
    /// handles).
    fwd_rem: Option<(usize, Arc<ForwardVariant>, MatrixMachine)>,
}

impl Session {
    /// Open `artifact` on `target`: machines are built on the artifact's
    /// cached per-device plans (compiled on first open, reused after).
    pub fn open(artifact: Arc<Artifact>, target: Target) -> Result<Session, Error> {
        let (device, cluster) = match target {
            Target::Board(d) => (d, None),
            Target::Cluster(c) => {
                let d = FpgaDevice::by_name(&c.device)
                    .ok_or_else(|| Error::UnknownDevice(c.device.clone()))?;
                (d, Some(c))
            }
        };
        let plans = artifact.plans_for(&device);
        let engine = match artifact.net() {
            Some(n) if n.train.is_some() => {
                let tr = n.train.as_ref().expect("trainable net");
                let train_machine =
                    MatrixMachine::with_plan(device, &tr.program, Arc::clone(&plans.primary))?;
                let fwd_machine = MatrixMachine::with_plan(
                    device,
                    &n.forward.program,
                    Arc::clone(&plans.forward),
                )?;
                let cfg = TrainConfig {
                    batch: n.batch,
                    lr: n.lr.expect("trainable net has lr"),
                    steps: 0,
                    ..TrainConfig::default()
                };
                match &n.spec {
                    NetSpec::Mlp(spec) => Engine::Trainable(Box::new(Trainer::from_parts(
                        spec.clone(),
                        device,
                        cfg,
                        tr.clone(),
                        n.forward.clone(),
                        train_machine,
                        fwd_machine,
                    ))),
                    NetSpec::Graph(g) => {
                        Engine::GraphTrainable(Box::new(GraphTrainer::from_parts(
                            g.clone(),
                            device,
                            cfg,
                            tr.clone(),
                            n.forward.clone(),
                            train_machine,
                            fwd_machine,
                        )))
                    }
                }
            }
            _ => Engine::Forward(Box::new(MatrixMachine::with_plan(
                device,
                artifact.program(),
                plans.primary,
            )?)),
        };
        Ok(Session {
            artifact,
            device,
            cluster,
            engine,
            weights_ready: false,
            sampler_seeded: false,
            fwd_rem: None,
        })
    }

    /// The artifact this session opened.
    pub fn artifact(&self) -> &Arc<Artifact> {
        &self.artifact
    }

    /// The board (or the cluster's board part) this session simulates.
    pub fn device(&self) -> FpgaDevice {
        self.device
    }

    /// The session's current on-device parameters as per-layer
    /// `(weights, biases)`, or `None` for raw / inference-only artifacts
    /// (their parameters live behind plain tensor handles). The testkit's
    /// differential executor reads these to assert bit-identical trained
    /// weights across fidelity levels.
    pub fn weights(&self) -> Option<(Vec<Vec<i16>>, Vec<Vec<i16>>)> {
        match &self.engine {
            Engine::Trainable(t) => Some(t.weights()),
            Engine::GraphTrainable(t) => Some(t.weights()),
            Engine::Forward(_) => None,
        }
    }

    /// Current per-layer parameters for any net-shaped artifact:
    /// trainable sessions read the trainer's on-device weights,
    /// inference-only sessions read the forward program's weight/bias
    /// tensors (whatever was last written through handles). `None` only
    /// for raw-program artifacts.
    fn current_params(&self) -> Option<(Vec<Vec<i16>>, Vec<Vec<i16>>)> {
        match &self.engine {
            Engine::Trainable(t) => Some(t.weights()),
            Engine::GraphTrainable(t) => Some(t.weights()),
            Engine::Forward(m) => {
                let n = self.artifact.net()?;
                let w = n.forward.weights.iter().map(|&id| m.read_id(id).to_vec()).collect();
                let b = n.forward.biases.iter().map(|&id| m.read_id(id).to_vec()).collect();
                Some((w, b))
            }
        }
    }

    /// Open a multi-tenant serving runtime on `cfg` with this session's
    /// artifact registered under its **current** parameters (trained
    /// weights for trainable sessions, handle-written parameters for
    /// inference-only ones). The registered net is id `0` of the new
    /// server; register more artifacts on it for multi-tenant serving.
    /// Served outputs are bit-identical to this session's `infer` on the
    /// same rows, and `cfg` carries the degraded-mode knobs — SLO
    /// shedding, fault plan, quarantine, hedged retries (see DESIGN.md
    /// §Serving).
    pub fn server(&self, cfg: serve::ServeConfig) -> Result<serve::Server, Error> {
        let (w, b) = self.current_params().ok_or_else(|| Error::Unsupported {
            verb: "server",
            why: "raw-program artifacts have no network structure".into(),
        })?;
        let mut srv = serve::Server::open(cfg)?;
        srv.register(Arc::clone(&self.artifact), &w, &b)?;
        Ok(srv)
    }

    fn machine(&self) -> &MatrixMachine {
        match &self.engine {
            Engine::Trainable(t) => t.primary_machine(),
            Engine::GraphTrainable(t) => t.primary_machine(),
            Engine::Forward(m) => m,
        }
    }

    fn machine_mut(&mut self) -> &mut MatrixMachine {
        match &mut self.engine {
            Engine::Trainable(t) => t.primary_machine_mut(),
            Engine::GraphTrainable(t) => t.primary_machine_mut(),
            Engine::Forward(m) => m,
        }
    }

    fn check_handle(&self, h: &TensorHandle) -> Result<(), Error> {
        if h.artifact() != self.artifact.fingerprint() {
            return Err(Error::ForeignHandle { name: h.name().to_string() });
        }
        Ok(())
    }

    /// Write quantised data to a tensor (length checked against the
    /// handle's compile-time shape). Writing a weight/bias tensor marks
    /// the session's parameters as user-provided: `train` will continue
    /// from them instead of re-initialising from the seed.
    pub fn write(&mut self, h: &TensorHandle, data: &[i16]) -> Result<(), Error> {
        self.check_handle(h)?;
        if data.len() != h.len() {
            return Err(Error::ShapeMismatch {
                name: h.name().to_string(),
                rows: h.rows(),
                cols: h.cols(),
                expect: h.len(),
                got: data.len(),
            });
        }
        self.machine_mut().write_id(h.id(), data)?;
        if h.is_param() {
            self.weights_ready = true;
            match &mut self.engine {
                Engine::Trainable(t) => t.mark_params_dirty(),
                Engine::GraphTrainable(t) => t.mark_params_dirty(),
                Engine::Forward(_) => {}
            }
        }
        Ok(())
    }

    /// Read a tensor after a run.
    ///
    /// Handles address the artifact's **primary** program state (the
    /// training-step machine for trainable artifacts). [`Session::infer`]
    /// executes on a separate forward instance and returns its output in
    /// [`Inference::output`] — read it from there, not from an output
    /// handle.
    pub fn read(&self, h: &TensorHandle) -> Result<Vec<i16>, Error> {
        self.check_handle(h)?;
        Ok(self.machine().read_id(h.id()).to_vec())
    }

    /// Execute the artifact's primary program once on the currently
    /// bound tensors (a training step for trainable artifacts — the
    /// on-device parameters mutate — a forward pass otherwise); the raw
    /// escape hatch under the verbs.
    pub fn step(&mut self) -> RunStats {
        match &mut self.engine {
            Engine::Trainable(t) => t.step_primary(),
            Engine::GraphTrainable(t) => t.step_primary(),
            Engine::Forward(m) => m.execute(),
        }
    }

    /// [`Session::step`] with per-wave structural verification against
    /// the microcode interpreters (slow; tests and `--verify` flows).
    pub fn step_verified(&mut self) -> Result<RunStats, Error> {
        Ok(self.machine_mut().execute_verified()?)
    }

    /// One forward pass over a quantised `batch × in_dim` input with the
    /// session's current parameters. The output lives in
    /// [`Inference::output`]; for trainable artifacts the pass runs on a
    /// separate forward instance, so output *handles* (which address the
    /// primary training state) do not observe it.
    pub fn infer(&mut self, qx: &[i16]) -> Result<Inference, Error> {
        match &mut self.engine {
            Engine::Trainable(t) => {
                let (output, stats) = t.infer(qx)?;
                Ok(Inference { output, stats })
            }
            Engine::GraphTrainable(t) => {
                let (output, stats) = t.infer(qx)?;
                Ok(Inference { output, stats })
            }
            Engine::Forward(m) => {
                let n = self.artifact.net().ok_or_else(|| Error::Unsupported {
                    verb: "infer",
                    why: "raw-program artifacts have no input/output structure; \
                          use step() with tensor handles"
                        .into(),
                })?;
                m.write_id(n.forward.x, qx)?;
                let stats = m.execute();
                Ok(Inference { output: m.read_id(n.forward.out).to_vec(), stats })
            }
        }
    }

    /// Train on `ds`. Board targets run the embedded engine; cluster
    /// targets dispatch one job to the cluster runtime (divided over the
    /// boards per §2) and adopt the averaged weights back into the
    /// session. `cfg.batch`/`cfg.lr` must match the artifact's compiled
    /// options.
    pub fn train(&mut self, ds: &Dataset, cfg: &TrainConfig) -> Result<TrainSummary, Error> {
        self.train_with(ds, cfg, &TrainOptions::default()).map(|(summary, _)| summary)
    }

    /// [`Session::train`] with deterministic checkpointing: snapshots
    /// are captured every [`TrainOptions::checkpoint_every`] steps and
    /// returned alongside the summary, and [`TrainOptions::resume`]
    /// continues a snapshotted run **bit-exactly** — `resume(k)` then
    /// training to the end reproduces the uninterrupted run's weights
    /// always, and its loss curve and stats too when resumed at the
    /// same checkpoint cadence (asserted for every captured `k` by
    /// `tests/recovery.rs`).
    pub fn train_with(
        &mut self,
        ds: &Dataset,
        cfg: &TrainConfig,
        opts: &TrainOptions,
    ) -> Result<(TrainSummary, Vec<TrainCheckpoint>), Error> {
        self.artifact.check_train_cfg(cfg)?;
        if let Some(ck) = &opts.resume {
            let net = self.artifact.net().expect("checked trainable");
            // One job on F boards divides over all of them when F > 1
            // (see `cluster::schedule`); otherwise the run is
            // single-board and the snapshot must say so too.
            let (replicas, sync_every, boards, sync) = match &self.cluster {
                Some(c) if c.boards > 1 => (c.boards, c.sync_every, c.boards, c.sync),
                Some(c) => (1, 0, c.boards, c.sync),
                None => (1, 0, 1, SyncPolicy::Star),
            };
            let run = RunIdentity {
                seed: cfg.seed,
                batch: cfg.batch,
                lr: cfg.lr,
                replicas,
                sync_every,
                boards,
                sync,
                total_steps: cfg.steps,
            };
            ck.check_resume(net.spec.name(), &run)?;
        }
        match self.cluster.clone() {
            Some(ccfg) => self.train_cluster_with(&ccfg, ds, cfg, opts),
            None => self.train_board_with(ds, cfg, opts),
        }
    }

    fn train_board_with(
        &mut self,
        ds: &Dataset,
        cfg: &TrainConfig,
        opts: &TrainOptions,
    ) -> Result<(TrainSummary, Vec<TrainCheckpoint>), Error> {
        if let Engine::GraphTrainable(t) = &mut self.engine {
            // Operator-graph board training: the same engine loop, but
            // checkpoint/resume is MLP-only for now ([`TrainCheckpoint`]
            // captures per-layer dims; a graph-aware snapshot format is
            // future work).
            if opts.checkpoint_every > 0 || opts.resume.is_some() {
                return Err(Error::Unsupported {
                    verb: "train",
                    why: "checkpoint/resume is not yet supported for operator-graph \
                          nets (snapshots capture MLP layer shapes)"
                        .into(),
                });
            }
            if !self.sampler_seeded {
                if self.weights_ready {
                    t.reseed(cfg.seed);
                } else {
                    t.init_params(cfg.seed)?;
                    self.weights_ready = true;
                }
                self.sampler_seeded = true;
            }
            t.cfg = cfg.clone();
            let report = t.train(ds)?;
            self.weights_ready = true;
            return Ok((
                TrainSummary {
                    curve: report.curve,
                    stats: report.stats,
                    sim_seconds: report.sim_seconds,
                    steps: report.steps,
                    boards: vec![0],
                    sync_rounds: 0,
                },
                Vec::new(),
            ));
        }
        let Engine::Trainable(t) = &mut self.engine else {
            unreachable!("check_train_cfg guarantees a trainable engine");
        };
        t.cfg = cfg.clone();
        let (mut done, mut curve, mut stats, mut compute_s) = match &opts.resume {
            Some(ck) => {
                // Deterministic resume: a seed init positions the
                // sampler stream exactly where a fresh run's would be,
                // the snapshot's parameters overwrite the seed weights,
                // and the sampler fast-forwards past the trained steps.
                t.init_weights(cfg.seed)?;
                let (w, b) = ck.weights();
                t.set_weights(&w, &b)?;
                t.skip_steps(ck.steps_done);
                self.weights_ready = true;
                self.sampler_seeded = true;
                (ck.steps_done, ck.curve.clone(), ck.stats, ck.sim_compute_s)
            }
            None => {
                // First train call seeds the batch sampler from
                // cfg.seed — also when weights were preloaded through
                // handles (the seed must not be silently ignored).
                // Later calls continue the stream.
                if !self.sampler_seeded {
                    if self.weights_ready {
                        t.reseed(cfg.seed);
                    } else {
                        t.init_weights(cfg.seed)?;
                        self.weights_ready = true;
                    }
                    self.sampler_seeded = true;
                }
                (0, Vec::new(), RunStats::default(), 0.0)
            }
        };
        let total = cfg.steps;
        let every = opts.checkpoint_every;
        let mut checkpoints = Vec::new();
        while done < total {
            let steps = if every > 0 { every.min(total - done) } else { total - done };
            t.cfg.steps = steps;
            let report = t.train(ds)?;
            curve.extend(report.curve.into_iter().map(|mut p| {
                p.step += done;
                p
            }));
            stats.add(&report.stats);
            compute_s += report.sim_seconds;
            done += steps;
            if every > 0 {
                let run = RunIdentity {
                    seed: cfg.seed,
                    batch: cfg.batch,
                    lr: cfg.lr,
                    replicas: 1,
                    sync_every: 0,
                    boards: 1,
                    sync: SyncPolicy::Star,
                    total_steps: total,
                };
                let (w, b) = t.weights();
                checkpoints.push(TrainCheckpoint::capture(
                    &t.spec, &run, done, &curve, stats, compute_s, &w, &b,
                ));
            }
        }
        t.cfg.steps = total;
        Ok((
            TrainSummary {
                curve,
                stats,
                sim_seconds: compute_s,
                steps: total,
                boards: vec![0],
                sync_rounds: 0,
            },
            checkpoints,
        ))
    }

    fn train_cluster_with(
        &mut self,
        ccfg: &ClusterConfig,
        ds: &Dataset,
        cfg: &TrainConfig,
        opts: &TrainOptions,
    ) -> Result<(TrainSummary, Vec<TrainCheckpoint>), Error> {
        if ds.is_empty() {
            return Err(Error::Unsupported { verb: "train", why: "empty dataset".into() });
        }
        let net = self.artifact.net().expect("checked trainable");
        let Some(mlp) = net.spec.as_mlp().cloned() else {
            return Err(Error::Unsupported {
                verb: "train",
                why: "cluster training dispatches MLP jobs; train operator-graph \
                      nets on a board target"
                    .into(),
            });
        };
        let (initial, resume) = match &opts.resume {
            Some(ck) => (Some(ck.weights()), Some(JobResume::from_checkpoint(ck))),
            None => {
                if self.weights_ready {
                    let Engine::Trainable(t) = &self.engine else {
                        unreachable!("trainable artifact has a trainer engine");
                    };
                    (Some(t.weights()), None)
                } else {
                    (None, None)
                }
            }
        };
        let mut ccfg = ccfg.clone();
        if opts.checkpoint_every > 0 {
            ccfg.recovery.checkpoint_every = opts.checkpoint_every;
        }
        // The cluster runtime always evaluates after training; give it a
        // single-row probe so that cost stays negligible (the session's
        // own `evaluate` is the real testing path).
        let probe = Dataset {
            x: vec![ds.x[0].clone()],
            y: vec![ds.y[0].clone()],
            classes: ds.classes,
            name: format!("{}-probe", ds.name),
        };
        let job = Job {
            name: mlp.name.clone(),
            spec: mlp,
            cfg: cfg.clone(),
            train_data: Arc::new(ds.clone()),
            test_data: Arc::new(probe),
            initial,
            resume,
        };
        let report = leader::execute(&ccfg, &[job])?;
        let jr = report.results.into_iter().next().expect("one job dispatched");
        // Adopt the cluster's final (averaged) parameters locally so
        // infer/evaluate see what the cluster trained.
        let Engine::Trainable(t) = &mut self.engine else {
            unreachable!("trainable artifact has a trainer engine");
        };
        t.set_weights(&jr.weights, &jr.biases)?;
        self.weights_ready = true;
        Ok((
            TrainSummary {
                curve: jr.curve,
                stats: jr.stats,
                sim_seconds: jr.sim_compute_s + jr.sim_bus_s,
                steps: jr.steps,
                boards: jr.boards,
                sync_rounds: report.metrics.sync_rounds,
            },
            jr.checkpoints,
        ))
    }

    /// Classification accuracy of the session's current parameters over
    /// `ds` (the paper's "testing" phase), chunked by
    /// [`dataset::chunk_ranges`].
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<Evaluation, Error> {
        match &mut self.engine {
            Engine::Trainable(t) => {
                let (accuracy, stats) = t.evaluate(ds)?;
                Ok(Evaluation { accuracy, stats })
            }
            Engine::GraphTrainable(t) => {
                let (accuracy, stats) = t.evaluate(ds)?;
                Ok(Evaluation { accuracy, stats })
            }
            Engine::Forward(m) => {
                let n = self.artifact.net().ok_or_else(|| Error::Unsupported {
                    verb: "evaluate",
                    why: "raw-program artifacts have no network structure".into(),
                })?;
                if ds.dim() != n.spec.input_dim() || ds.classes != n.spec.output_dim() {
                    return Err(crate::nn::trainer::TrainError::DimMismatch(
                        ds.dim(),
                        ds.classes,
                        n.spec.input_dim(),
                        n.spec.output_dim(),
                    )
                    .into());
                }
                let f = n.spec.fixed();
                let batch = n.batch;
                // The partial remainder chunk runs on a right-sized
                // forward-ladder variant from the artifact (compiled
                // once per `(net, rows, device)`, shared with the
                // serving runtime), cached in the session across
                // evaluate calls and refreshed with the session
                // machine's current parameters on every pass.
                let rem = ds.len() % batch;
                if rem != 0 {
                    if self.fwd_rem.as_ref().is_none_or(|(rows, _, _)| *rows != rem) {
                        let variant = self.artifact.forward_variant(rem)?;
                        let machine = variant.machine(self.device)?;
                        self.fwd_rem = Some((rem, variant, machine));
                    }
                    let (_, variant, machine) =
                        self.fwd_rem.as_mut().expect("just built");
                    for l in 0..n.forward.weights.len() {
                        let w = m.read_id(n.forward.weights[l]).to_vec();
                        let b = m.read_id(n.forward.biases[l]).to_vec();
                        machine.write_id(variant.lowered().weights[l], &w)?;
                        machine.write_id(variant.lowered().biases[l], &b)?;
                    }
                }
                let mut stats = RunStats::default();
                let mut correct = 0usize;
                for r in dataset::chunk_ranges(ds.len(), batch) {
                    let qx = ds.encode_rows(r.clone(), f);
                    let (machine, x_id, out_id) = if r.len() == batch {
                        (&mut **m, n.forward.x, n.forward.out)
                    } else {
                        let (_, variant, machine) = self
                            .fwd_rem
                            .as_mut()
                            .expect("partial-chunk engine built above");
                        (machine, variant.lowered().x, variant.lowered().out)
                    };
                    machine.write_id(x_id, &qx)?;
                    stats.add(&machine.execute());
                    correct += ds.count_correct(r, machine.read_id(out_id), f);
                }
                Ok(Evaluation { accuracy: correct as f64 / ds.len().max(1) as f64, stats })
            }
        }
    }

    /// The paper's headline M×F workload in one call: train/test many
    /// compiled nets on an F-board cluster, scheduled per §2 (sequential
    /// queues when M > F, 1:1 when M = F, divided data-parallel groups
    /// when M < F).
    pub fn train_many(cfg: &ClusterConfig, jobs: &[NetJob]) -> Result<ClusterReport, Error> {
        let placement = crate::cluster::schedule(jobs.len(), cfg.boards);
        let mut cluster_jobs = Vec::with_capacity(jobs.len());
        for (ji, j) in jobs.iter().enumerate() {
            j.artifact.check_train_cfg(&j.cfg)?;
            let net = j.artifact.net().expect("checked trainable");
            let Some(mlp) = net.spec.as_mlp().cloned() else {
                return Err(Error::Unsupported {
                    verb: "train_many",
                    why: format!(
                        "net {:?}: cluster training dispatches MLP jobs; train \
                         operator-graph nets on a board target",
                        net.spec.name()
                    ),
                });
            };
            let (initial, resume) = match &j.resume {
                Some(ck) => {
                    use crate::cluster::PlacementMode;
                    let (replicas, sync_every) = match placement.mode {
                        PlacementMode::Divided => {
                            (placement.groups[ji].len(), cfg.sync_every)
                        }
                        _ => (1, 0),
                    };
                    let run = RunIdentity {
                        seed: j.cfg.seed,
                        batch: j.cfg.batch,
                        lr: j.cfg.lr,
                        replicas,
                        sync_every,
                        boards: cfg.boards,
                        sync: cfg.sync,
                        total_steps: j.cfg.steps,
                    };
                    ck.check_resume(&mlp.name, &run)?;
                    (Some(ck.weights()), Some(JobResume::from_checkpoint(ck)))
                }
                None => (None, None),
            };
            cluster_jobs.push(Job {
                name: mlp.name.clone(),
                spec: mlp,
                cfg: j.cfg.clone(),
                train_data: Arc::clone(&j.train),
                test_data: Arc::clone(&j.test),
                initial,
                resume,
            });
        }
        Ok(leader::execute(cfg, &cluster_jobs)?)
    }
}
