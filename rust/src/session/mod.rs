//! The **unified session front door**: compile once, open anywhere, run
//! any workload.
//!
//! The paper's headline capability — *any* network, trained or tested,
//! on *any* number of FPGAs — used to be reachable only through three
//! disjoint, stringly-typed entry points (`asm::lower_file` + manual
//! `MatrixMachine` driving, `nn::Trainer`, `cluster::run_cluster`).
//! This module is the single front door on top of those engines:
//!
//! ```text
//!   .masm source ──┐
//!   MlpSpec ───────┤→ Compiler ──→ Artifact ──→ Session(Target) ──→ infer
//!   raw Program ───┘   (cached      (programs +   Board | Cluster     train
//!                       by net)      symbols +                        evaluate
//!                                    per-device ExecPlans)
//! ```
//!
//! * [`Compiler`] turns assembly text, an [`crate::nn::MlpSpec`], an
//!   operator-graph [`crate::nn::GraphSpec`] (CNNs, residual blocks,
//!   transformer blocks — [`Compiler::compile_graph`]), or a
//!   raw validated [`crate::assembler::program::Program`] into an
//!   immutable [`Artifact`] — validated program(s), the tensor
//!   [`crate::assembler::program::SymbolTable`], and a per-device cache
//!   of compiled [`crate::hw::ExecPlan`]s. Same net ⇒ same `Arc`;
//!   `(net, device)` plans are built exactly once.
//! * [`Session::open`] places an artifact on a [`Target`] —
//!   [`Target::Board`] for one simulated FPGA, [`Target::Cluster`] for
//!   the multi-FPGA runtime — and exposes typed [`TensorHandle`]s
//!   (resolved once at compile time, length-checked against the handle,
//!   misses answered with "did you mean …") plus the three uniform
//!   verbs `infer` / `train` / `evaluate` and the raw `step` escape
//!   hatch. [`Session::train_many`] runs the paper's M×F workload over
//!   many artifacts in one call; [`Session::server`] opens the
//!   multi-tenant serving runtime ([`crate::serve`]) preloaded with the
//!   session's artifact and current parameters.
//! * Artifacts carry a **forward batch ladder**
//!   ([`Artifact::forward_variant`] / [`ForwardVariant`]): one lowered
//!   forward program + cached [`crate::hw::ExecPlan`] per requested
//!   batch size, shared by evaluation's partial chunks and every
//!   serving engine on every board.
//! * [`enum@Error`] is the crate-wide error: every layer's error type
//!   folds into it via `#[from]`.
//!
//! The old entry points remain as thin `#[deprecated]` shims
//! (`nn::Trainer::new`, `cluster::run_cluster`,
//! `hw::MatrixMachine::{bind, read, run, run_verified}`) delegating to
//! the engines this module drives; they will be removed one release
//! after the redesign.

pub mod artifact;
pub mod compiler;
pub mod error;
#[allow(clippy::module_inception)]
pub mod session;

pub use artifact::{Artifact, ForwardVariant, NetSpec, TensorHandle};
pub use compiler::{CompileOptions, Compiler};
pub use error::Error;
pub use session::{
    Evaluation, Inference, NetJob, Session, Target, TrainOptions, TrainSummary,
};
