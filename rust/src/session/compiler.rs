//! The compile-once front end: source / spec / program → [`Artifact`].
//!
//! The compiler owns the artifact cache. Compiling the same net twice
//! (same assembly source, or same spec + options) returns the same
//! `Arc<Artifact>`; per-device [`crate::hw::ExecPlan`]s are cached inside
//! the artifact, so `(net, device)` pairs are compiled exactly once no
//! matter how many sessions open them.

use super::artifact::{Artifact, NetInfo, NetSpec, Payload};
use super::error::Error;
use crate::analysis::{check_program, CheckLevel, CheckOptions, CheckReport};
use crate::asm::lower_file;
use crate::assembler::program::Program;
use crate::hw::memplan::MemPlan;
use crate::nn::graph::{lower_graph_forward, lower_graph_train, lower_mlp_forward, lower_mlp_train};
use crate::nn::{precision, GraphSpec, MlpSpec};
use crate::perf::catalog::FpgaPart;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Seed for the precision search's deterministic oracle/probe batch:
/// the same spec + budget always picks the same formats.
const PRECISION_SEED: u64 = 0x9E3779B97F4A7C15;

/// What to compile a spec for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompileOptions {
    /// Batch size (input rows) both programs are lowered for.
    pub batch: usize,
    /// `Some(lr)` compiles a training-step program alongside the forward
    /// program; `None` compiles an inference-only artifact.
    pub lr: Option<f64>,
    /// Compile every [`crate::hw::ExecPlan`] with the static memory
    /// planner's lane-reuse layout (DESIGN.md §Memory planner). Outputs
    /// and `RunStats` stay bit-identical to the packed layout; board fit
    /// is validated at compile time against the selected part
    /// ([`crate::hw::memplan::PlanError::ExceedsBoard`] on overflow).
    pub memory_plan: bool,
    /// `Some(budget)` runs [`crate::nn::precision::search`] before
    /// lowering: the datapath format is narrowed to the searched
    /// per-layer requirement (never widened) within the given max-abs
    /// output-error budget. MLP specs only — graph compiles reject it.
    pub precision_search: Option<f64>,
    /// Run the static program checker (DESIGN.md §Static analysis) over
    /// every lowered program: hard errors abort the compile as
    /// [`Error::Check`]; the per-program [`crate::analysis::CheckReport`]s
    /// attach to the artifact ([`Artifact::check_reports`]). `Off` (the
    /// default) skips the checker entirely.
    pub checks: CheckLevel,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            batch: 16,
            lr: None,
            memory_plan: false,
            precision_search: None,
            checks: CheckLevel::Off,
        }
    }
}

impl CompileOptions {
    /// Inference-only artifact at `batch` rows.
    pub fn inference(batch: usize) -> CompileOptions {
        CompileOptions { batch, ..CompileOptions::default() }
    }

    /// Trainable artifact at `batch` rows with learning rate `lr`.
    pub fn training(batch: usize, lr: f64) -> CompileOptions {
        CompileOptions { batch, lr: Some(lr), ..CompileOptions::default() }
    }

    /// Same options with the static memory planner enabled.
    pub fn with_memory_plan(mut self) -> CompileOptions {
        self.memory_plan = true;
        self
    }

    /// Same options with per-layer precision search at `budget` max abs
    /// output error.
    pub fn with_precision_search(mut self, budget: f64) -> CompileOptions {
        self.precision_search = Some(budget);
        self
    }

    /// Same options with the static program checker at `level`.
    pub fn with_checks(mut self, level: CheckLevel) -> CompileOptions {
        self.checks = level;
        self
    }

    /// Inference artifact for the serving runtime: compiled at
    /// `max_batch` (the top bucket of the forward batch ladder), so the
    /// artifact's own forward program doubles as the full-bucket serving
    /// plan and the smaller buckets
    /// ([`crate::nn::lowering::forward_buckets`]) lower lazily through
    /// [`super::Artifact::forward_variant`] on first use.
    pub fn serving(max_batch: usize) -> CompileOptions {
        CompileOptions::inference(max_batch)
    }
}

/// The compile-once front end. Cheap to create; share one per process to
/// get cross-session artifact caching.
///
/// ```
/// use mfnn::session::{CompileOptions, Compiler};
/// use mfnn::fixed::FixedSpec;
/// use mfnn::nn::lut::ActKind;
/// use mfnn::nn::mlp::{LutParams, MlpSpec};
/// use std::sync::Arc;
///
/// let compiler = Compiler::new();
/// // From assembly text (one artifact per NET block):
/// let nets = compiler.compile_asm("
/// NET doc
/// INPUT x 4 2
/// WEIGHT w 2 2
/// BIAS b 2
/// ACT a relu
/// MLP o x w b a
/// OUTPUT o
/// ").unwrap();
/// assert_eq!(nets.len(), 1);
/// assert_eq!(nets[0].name(), "doc");
/// // Compile-once: the same source returns the same artifact.
/// let again = compiler.compile_asm_net("
/// NET doc
/// INPUT x 4 2
/// WEIGHT w 2 2
/// BIAS b 2
/// ACT a relu
/// MLP o x w b a
/// OUTPUT o
/// ").unwrap();
/// assert!(Arc::ptr_eq(&nets[0], &again));
///
/// // From a spec:
/// let fixed = FixedSpec::q(10).saturating();
/// let spec = MlpSpec::from_dims(
///     "s", &[2, 4, 2], ActKind::Relu, ActKind::Identity,
///     fixed, LutParams::training(fixed),
/// ).unwrap();
/// let a = compiler.compile_spec(&spec, &CompileOptions::training(8, 1.0 / 128.0)).unwrap();
/// assert!(a.trainable());
/// assert_eq!(a.batch(), Some(8));
/// ```
#[derive(Default)]
pub struct Compiler {
    asm_cache: Mutex<HashMap<String, Vec<Arc<Artifact>>>>,
    net_cache: Mutex<HashMap<String, Arc<Artifact>>>,
}

impl Compiler {
    /// New compiler with empty caches.
    pub fn new() -> Compiler {
        Compiler::default()
    }

    /// Number of cached artifacts (diagnostics/tests).
    pub fn cached(&self) -> usize {
        self.net_cache.lock().expect("cache poisoned").len()
            + self
                .asm_cache
                .lock()
                .expect("cache poisoned")
                .values()
                .map(Vec::len)
                .sum::<usize>()
    }

    /// Compile assembly text: one artifact per `NET` block. Training nets
    /// (`TRAIN` directive) produce trainable artifacts; a forward program
    /// is lowered alongside for `infer`/`evaluate`.
    pub fn compile_asm(&self, source: &str) -> Result<Vec<Arc<Artifact>>, Error> {
        if let Some(hit) = self.asm_cache.lock().expect("cache poisoned").get(source) {
            return Ok(hit.clone());
        }
        let nets = lower_file(source)?;
        let mut artifacts = Vec::with_capacity(nets.len());
        for net in nets {
            let (forward, train) = if net.train {
                (lower_mlp_forward(&net.spec, net.batch)?, Some(net.mlp))
            } else {
                (net.mlp, None)
            };
            let key = format!("asm::{}::{}", net.spec.name, source);
            artifacts.push(Arc::new(Artifact::new(
                key,
                Payload::Net(NetInfo {
                    spec: NetSpec::Mlp(net.spec),
                    batch: net.batch,
                    lr: net.lr,
                    forward,
                    train,
                    memory_plan: false,
                }),
            )));
        }
        self.asm_cache
            .lock()
            .expect("cache poisoned")
            .insert(source.to_string(), artifacts.clone());
        Ok(artifacts)
    }

    /// Compile assembly text that defines exactly one `NET`.
    pub fn compile_asm_net(&self, source: &str) -> Result<Arc<Artifact>, Error> {
        let mut nets = self.compile_asm(source)?;
        if nets.len() != 1 {
            return Err(Error::Unsupported {
                verb: "compile_asm_net",
                why: format!("source defines {} nets, expected exactly 1", nets.len()),
            });
        }
        Ok(nets.remove(0))
    }

    /// Compile an [`MlpSpec`] (validated first). With
    /// [`CompileOptions::training`] the artifact carries both the
    /// training-step and the forward program; with
    /// [`CompileOptions::inference`] only the forward program.
    pub fn compile_spec(
        &self,
        spec: &MlpSpec,
        opts: &CompileOptions,
    ) -> Result<Arc<Artifact>, Error> {
        spec.check()?;
        // Exact structural key — no hash collisions, cheap at this scale.
        let key = format!(
            "spec::{spec:?}::batch={}::lr={:?}::plan={}::prec={:?}::checks={:?}",
            opts.batch,
            opts.lr.map(f64::to_bits),
            opts.memory_plan,
            opts.precision_search.map(f64::to_bits),
            opts.checks
        );
        if let Some(hit) = self.net_cache.lock().expect("cache poisoned").get(&key) {
            return Ok(Arc::clone(hit));
        }
        // Precision search: narrow the datapath format within the error
        // budget (never wider than the spec's own format).
        let spec = match opts.precision_search {
            Some(budget) => precision::search_spec(spec, budget, PRECISION_SEED).apply(spec),
            None => spec.clone(),
        };
        let forward = lower_mlp_forward(&spec, opts.batch)?;
        let train = match opts.lr {
            Some(lr) => Some(lower_mlp_train(&spec, opts.batch, lr)?),
            None => None,
        };
        self.check_board_fit(opts, &forward.program, train.as_ref().map(|t| &t.program))?;
        let reports =
            self.run_checks(opts, &forward.program, train.as_ref().map(|t| &t.program))?;
        let artifact = Arc::new(
            Artifact::new(
                key.clone(),
                Payload::Net(NetInfo {
                    spec: NetSpec::Mlp(spec),
                    batch: opts.batch,
                    lr: opts.lr,
                    forward,
                    train,
                    memory_plan: opts.memory_plan,
                }),
            )
            .with_check_reports(reports),
        );
        self.net_cache
            .lock()
            .expect("cache poisoned")
            .insert(key, Arc::clone(&artifact));
        Ok(artifact)
    }

    /// Compile a [`GraphSpec`] operator graph — the graph twin of
    /// [`Compiler::compile_spec`], same caching contract. The artifact
    /// flows through the same `Artifact`/`Session`/serving machinery as
    /// MLP artifacts (graph identity is first-class — see
    /// [`super::artifact::NetSpec`]).
    pub fn compile_graph(
        &self,
        spec: &GraphSpec,
        opts: &CompileOptions,
    ) -> Result<Arc<Artifact>, Error> {
        spec.check().map_err(crate::nn::lowering::LowerError::from)?;
        if opts.precision_search.is_some() {
            return Err(Error::Unsupported {
                verb: "compile_graph",
                why: "precision search requires an MLP spec (the float_ref oracle)".into(),
            });
        }
        let key = format!(
            "graph::{spec:?}::batch={}::lr={:?}::plan={}::checks={:?}",
            opts.batch,
            opts.lr.map(f64::to_bits),
            opts.memory_plan,
            opts.checks
        );
        if let Some(hit) = self.net_cache.lock().expect("cache poisoned").get(&key) {
            return Ok(Arc::clone(hit));
        }
        let forward = lower_graph_forward(spec, opts.batch)?;
        let train = match opts.lr {
            Some(lr) => Some(lower_graph_train(spec, opts.batch, lr)?),
            None => None,
        };
        self.check_board_fit(opts, &forward.program, train.as_ref().map(|t| &t.program))?;
        let reports =
            self.run_checks(opts, &forward.program, train.as_ref().map(|t| &t.program))?;
        let artifact = Arc::new(
            Artifact::new(
                key.clone(),
                Payload::Net(NetInfo {
                    spec: NetSpec::Graph(spec.clone()),
                    batch: opts.batch,
                    lr: opts.lr,
                    forward,
                    train,
                    memory_plan: opts.memory_plan,
                }),
            )
            .with_check_reports(reports),
        );
        self.net_cache
            .lock()
            .expect("cache poisoned")
            .insert(key, Arc::clone(&artifact));
        Ok(artifact)
    }

    /// When the memory planner is requested, validate at compile time
    /// that both programs' planned peak lane demand fits the selected
    /// board — a typed [`crate::hw::memplan::PlanError::ExceedsBoard`]
    /// (with a suggested split point) instead of a silent allocation.
    fn check_board_fit(
        &self,
        opts: &CompileOptions,
        forward: &Program,
        train: Option<&Program>,
    ) -> Result<(), Error> {
        if !opts.memory_plan {
            return Ok(());
        }
        let part = FpgaPart::selected();
        MemPlan::fit(forward, part)?;
        if let Some(t) = train {
            MemPlan::fit(t, part)?;
        }
        Ok(())
    }

    /// Run the static checker (DESIGN.md §Static analysis) over every
    /// lowered program when `opts.checks` is above `Off`. Hard errors
    /// (proven defects) abort the compile as [`Error::Check`]; clean or
    /// warnings-only reports attach to the artifact in forward-then-train
    /// order.
    fn run_checks(
        &self,
        opts: &CompileOptions,
        forward: &Program,
        train: Option<&Program>,
    ) -> Result<Vec<CheckReport>, Error> {
        if opts.checks == CheckLevel::Off {
            return Ok(Vec::new());
        }
        let copts = CheckOptions::new(opts.checks);
        let mut reports = vec![check_program(forward, &copts).into_result()?];
        if let Some(t) = train {
            reports.push(check_program(t, &copts).into_result()?);
        }
        Ok(reports)
    }

    /// Wrap a raw vector [`Program`] (validated) in an artifact: tensor
    /// handles and [`super::Session::step`] work; the net-shaped verbs
    /// (`infer`/`train`/`evaluate`) do not. Raw artifacts are not
    /// deduplicated in the compiler cache (their per-device plan cache
    /// still applies).
    pub fn compile_program(&self, program: &Program) -> Result<Arc<Artifact>, Error> {
        program.check()?;
        // Fingerprint the full structure, not just the name: two distinct
        // programs sharing a name must not satisfy the foreign-handle
        // guard against each other's sessions.
        let key = format!("raw::{program:?}");
        Ok(Arc::new(Artifact::new(key, Payload::Raw(program.clone()))))
    }
}
