//! The crate-wide [`Error`] type — one error for the whole front door.
//!
//! Before the session redesign every layer had its own error
//! (`ParseError`, `AsmError`, `LowerError`, `ProgramError`,
//! `MachineError`, `TrainError`, `ClusterError`) and every caller
//! re-plumbed conversions between them. They all fold into
//! [`enum@Error`] via `#[from]`, so `?` works from any layer, and the
//! session adds the typed-handle diagnostics the old stringly paths
//! could not express (unknown-tensor suggestions, foreign handles,
//! shape mismatches, artifact/config disagreements).

use crate::asm::{AsmError, ParseError};
use crate::assembler::program::ProgramError;
use crate::cluster::leader::ClusterError;
use crate::hw::machine::MachineError;
use crate::nn::lowering::LowerError;
use crate::nn::mlp::SpecError;
use crate::nn::trainer::TrainError;
use thiserror::Error;

/// Unified `mfnn` error: every layer's error converts in via `#[from]`.
#[derive(Debug, Error)]
pub enum Error {
    /// Assembly text failed to parse.
    #[error(transparent)]
    Parse(#[from] ParseError),
    /// Assembly semantic analysis / lowering failed.
    #[error(transparent)]
    Asm(#[from] AsmError),
    /// MLP specification invalid.
    #[error(transparent)]
    Spec(#[from] SpecError),
    /// Lowering a spec onto the vector ISA failed.
    #[error(transparent)]
    Lower(#[from] LowerError),
    /// Vector program failed validation.
    #[error(transparent)]
    Program(#[from] ProgramError),
    /// The Matrix Machine rejected a bind/run.
    #[error(transparent)]
    Machine(#[from] MachineError),
    /// The training engine failed.
    #[error(transparent)]
    Train(#[from] TrainError),
    /// The multi-FPGA cluster runtime failed.
    #[error(transparent)]
    Cluster(#[from] ClusterError),
    /// The static memory planner rejected a net for the configured board
    /// (peak lane demand exceeds its BRAM capacity — see
    /// [`crate::hw::memplan::PlanError`] for the suggested split point).
    #[error(transparent)]
    Plan(#[from] crate::hw::memplan::PlanError),
    /// A checkpoint could not be read/written or failed validation
    /// (bad magic, truncation, integrity-checksum mismatch, resume
    /// against the wrong run).
    #[error(transparent)]
    Checkpoint(#[from] crate::nn::checkpoint::CheckpointError),
    /// The multi-tenant serving runtime failed (typed shed /
    /// deadline-exceeded rejections, degraded-mode pool exhaustion,
    /// admission/config errors — see [`crate::serve::ServeError`]).
    #[error(transparent)]
    Serve(#[from] crate::serve::ServeError),
    /// The static program checker proved a defect in a compiled program
    /// (undefined-lane read, guaranteed fixed-point overflow, ring-FIFO
    /// overrun, or an unsound plan claim — see
    /// [`crate::analysis::CheckError`]). Raised when compiling with
    /// [`crate::analysis::CheckLevel`] above `Off`.
    #[error(transparent)]
    Check(#[from] crate::analysis::CheckError),
    /// Tensor name not found in the artifact's symbol table (`hint` is
    /// the pre-rendered ", did you mean …?" suffix, possibly empty).
    #[error("unknown tensor {name:?} in artifact {artifact:?}{hint}")]
    UnknownTensor {
        /// Artifact (net) name.
        artifact: String,
        /// The name that missed.
        name: String,
        /// Pre-rendered suggestion suffix.
        hint: String,
    },
    /// A handle from a different artifact was presented to a session.
    #[error("tensor handle {name:?} belongs to a different artifact")]
    ForeignHandle {
        /// The handle's tensor name.
        name: String,
    },
    /// Data length does not match the handle's compile-time shape.
    #[error("tensor {name:?} is {rows}×{cols} ({expect} lanes), got {got}")]
    ShapeMismatch {
        /// Tensor name.
        name: String,
        /// Declared rows.
        rows: usize,
        /// Declared cols.
        cols: usize,
        /// Expected lane count.
        expect: usize,
        /// Provided lane count.
        got: usize,
    },
    /// Verb not available for this artifact/target combination.
    #[error("{verb} is not available: {why}")]
    Unsupported {
        /// The session verb that was called.
        verb: &'static str,
        /// Why it cannot run.
        why: String,
    },
    /// A `TrainConfig` field disagrees with what the artifact was
    /// compiled for (compile-once: recompile with matching options).
    #[error(
        "train config {what} = {requested} does not match the artifact's \
         compiled {what} = {compiled}; recompile the artifact with \
         matching options"
    )]
    ConfigMismatch {
        /// Which field disagreed (`"batch"` / `"lr"`).
        what: &'static str,
        /// The artifact's compiled value.
        compiled: String,
        /// The requested value.
        requested: String,
    },
    /// Unknown FPGA part name in a cluster target.
    #[error("unknown FPGA part {0:?}")]
    UnknownDevice(String),
}
