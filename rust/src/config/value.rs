//! Config value type.

use std::fmt;

/// A TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// 64-bit signed integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneity is *not* enforced at parse time; typed accessors check.
    Array(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_roundtrippable_forms() {
        assert_eq!(Value::Int(3).to_string(), "3");
        assert_eq!(Value::Bool(true).to_string(), "true");
        assert_eq!(Value::Str("hi".into()).to_string(), "\"hi\"");
        assert_eq!(
            Value::Array(vec![Value::Int(1), Value::Int(2)]).to_string(),
            "[1, 2]"
        );
    }
}
