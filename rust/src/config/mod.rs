//! Configuration system: a TOML-subset parser + typed accessors.
//!
//! The sandbox vendors no `serde`/`toml`, so this is a from-scratch parser
//! for the subset we use in launcher configs (`configs/*.toml`):
//! `[section.sub]` headers, `key = value` pairs with string / integer /
//! float / boolean / homogeneous-array values, `#` comments. Keys are
//! exposed flattened with dots: `cluster.num_fpgas`.

mod parse;
mod value;

pub use parse::{parse_document, ConfigError};
pub use value::Value;

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config document: flattened dotted keys → values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    /// Parse from TOML-subset text.
    pub fn from_str(text: &str) -> Result<Config, ConfigError> {
        Ok(Config { map: parse_document(text)? })
    }

    /// Parse from a file.
    pub fn from_file(path: &Path) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Io(path.display().to_string(), e.to_string()))?;
        Config::from_str(&text)
    }

    /// Empty config.
    pub fn empty() -> Config {
        Config::default()
    }

    /// Insert / override a value programmatically (CLI overrides).
    pub fn set<S: Into<String>>(&mut self, key: S, value: Value) {
        self.map.insert(key.into(), value);
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// All keys under a dotted prefix (e.g. `"mlp."`).
    pub fn keys_with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        self.map.keys().filter(move |k| k.starts_with(prefix)).map(|k| k.as_str())
    }

    /// Typed lookup: string.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.map.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Typed lookup: integer.
    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.map.get(key) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    /// Typed lookup: float (integers coerce).
    pub fn get_float(&self, key: &str) -> Option<f64> {
        match self.map.get(key) {
            Some(Value::Float(f)) => Some(*f),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    /// Typed lookup: boolean.
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.map.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Typed lookup: array of integers.
    pub fn get_int_array(&self, key: &str) -> Option<Vec<i64>> {
        match self.map.get(key) {
            Some(Value::Array(xs)) => {
                xs.iter().map(|v| if let Value::Int(i) = v { Some(*i) } else { None }).collect()
            }
            _ => None,
        }
    }

    /// Typed lookup: array of strings.
    pub fn get_str_array(&self, key: &str) -> Option<Vec<String>> {
        match self.map.get(key) {
            Some(Value::Array(xs)) => xs
                .iter()
                .map(|v| if let Value::Str(s) = v { Some(s.clone()) } else { None })
                .collect(),
            _ => None,
        }
    }

    /// Integer with default.
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get_int(key).unwrap_or(default)
    }

    /// Float with default.
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get_float(key).unwrap_or(default)
    }

    /// Bool with default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get_bool(key).unwrap_or(default)
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get_str(key).unwrap_or(default).to_string()
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# launcher config
title = "demo"

[cluster]
num_fpgas = 4
device = "XC7S75-2"
oversubscribe = false

[mlp]
layers = [64, 32, 10]
lr = 0.0078125
names = ["a", "b"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::from_str(SAMPLE).unwrap();
        assert_eq!(c.get_str("title"), Some("demo"));
        assert_eq!(c.get_int("cluster.num_fpgas"), Some(4));
        assert_eq!(c.get_str("cluster.device"), Some("XC7S75-2"));
        assert_eq!(c.get_bool("cluster.oversubscribe"), Some(false));
        assert_eq!(c.get_int_array("mlp.layers"), Some(vec![64, 32, 10]));
        assert_eq!(c.get_float("mlp.lr"), Some(0.0078125));
        assert_eq!(c.get_str_array("mlp.names"), Some(vec!["a".into(), "b".into()]));
    }

    #[test]
    fn defaults_and_coercion() {
        let c = Config::from_str(SAMPLE).unwrap();
        assert_eq!(c.int_or("cluster.num_fpgas", 1), 4);
        assert_eq!(c.int_or("missing", 7), 7);
        // int coerces to float
        assert_eq!(c.get_float("cluster.num_fpgas"), Some(4.0));
        // but not the reverse via get_int
        assert_eq!(c.get_int("mlp.lr"), None);
    }

    #[test]
    fn prefix_iteration() {
        let c = Config::from_str(SAMPLE).unwrap();
        let keys: Vec<&str> = c.keys_with_prefix("cluster.").collect();
        assert_eq!(keys, vec!["cluster.device", "cluster.num_fpgas", "cluster.oversubscribe"]);
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::from_str(SAMPLE).unwrap();
        c.set("cluster.num_fpgas", Value::Int(8));
        assert_eq!(c.get_int("cluster.num_fpgas"), Some(8));
    }
}
