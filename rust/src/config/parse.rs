//! TOML-subset parser (hand-rolled; no external deps available).

use super::value::Value;
use std::collections::BTreeMap;
use thiserror::Error;

/// Parse errors with line numbers.
#[derive(Debug, Error, PartialEq)]
pub enum ConfigError {
    /// I/O failure reading a config file.
    #[error("cannot read config {0}: {1}")]
    Io(String, String),
    /// Syntax error at a given 1-based line.
    #[error("config syntax error at line {0}: {1}")]
    Syntax(usize, String),
    /// The same key appears twice.
    #[error("duplicate key {0:?} at line {1}")]
    DuplicateKey(String, usize),
}

/// Parse a document into flattened dotted keys.
pub fn parse_document(text: &str) -> Result<BTreeMap<String, Value>, ConfigError> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| ConfigError::Syntax(lineno, "unterminated section header".into()))?
                .trim();
            if name.is_empty() || !name.split('.').all(is_valid_key) {
                return Err(ConfigError::Syntax(lineno, format!("bad section name {name:?}")));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| ConfigError::Syntax(lineno, "expected `key = value`".into()))?;
        let key = line[..eq].trim();
        if !is_valid_key(key) {
            return Err(ConfigError::Syntax(lineno, format!("bad key {key:?}")));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if map.contains_key(&full) {
            return Err(ConfigError::DuplicateKey(full, lineno));
        }
        map.insert(full, value);
    }
    Ok(map)
}

fn is_valid_key(k: &str) -> bool {
    !k.is_empty()
        && k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, ConfigError> {
    if s.is_empty() {
        return Err(ConfigError::Syntax(lineno, "missing value".into()));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| ConfigError::Syntax(lineno, "unterminated string".into()))?;
        // Minimal escapes: \\ \" \n \t. A bare `"` inside the body (i.e. not
        // escaped) means the string terminated early → malformed line.
        let mut out = String::with_capacity(body.len());
        let mut chars = body.chars();
        while let Some(c) = chars.next() {
            if c == '"' {
                return Err(ConfigError::Syntax(lineno, "unescaped quote in string".into()));
            }
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => {
                        return Err(ConfigError::Syntax(
                            lineno,
                            format!("bad escape \\{}", other.map(String::from).unwrap_or_default()),
                        ))
                    }
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| ConfigError::Syntax(lineno, "unterminated array".into()))?
            .trim();
        if body.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        // No nested arrays in our subset; split on commas outside strings.
        let mut items = Vec::new();
        let mut depth_str = false;
        let mut start = 0usize;
        for (i, c) in body.char_indices() {
            match c {
                '"' => depth_str = !depth_str,
                ',' if !depth_str => {
                    items.push(parse_value(body[start..i].trim(), lineno)?);
                    start = i + 1;
                }
                _ => {}
            }
        }
        items.push(parse_value(body[start..].trim(), lineno)?);
        return Ok(Value::Array(items));
    }
    // number: int if it parses as i64 and has no '.', 'e' etc.
    if s.chars().all(|c| c.is_ascii_digit() || c == '-' || c == '+' || c == '_')
        && s.chars().any(|c| c.is_ascii_digit())
    {
        let cleaned: String = s.chars().filter(|&c| c != '_').collect();
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ConfigError::Syntax(lineno, format!("cannot parse value {s:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let m = parse_document("a = 1\nb = -2\nc = 1_000\nd = 2.5\ne = true\nf = \"x\"").unwrap();
        assert_eq!(m["a"], Value::Int(1));
        assert_eq!(m["b"], Value::Int(-2));
        assert_eq!(m["c"], Value::Int(1000));
        assert_eq!(m["d"], Value::Float(2.5));
        assert_eq!(m["e"], Value::Bool(true));
        assert_eq!(m["f"], Value::Str("x".into()));
    }

    #[test]
    fn comments_and_blank_lines() {
        let m = parse_document("# top\n\na = 1 # trailing\nb = \"has # inside\"\n").unwrap();
        assert_eq!(m["a"], Value::Int(1));
        assert_eq!(m["b"], Value::Str("has # inside".into()));
    }

    #[test]
    fn nested_sections_flatten() {
        let m = parse_document("[a.b]\nc = 1").unwrap();
        assert_eq!(m["a.b.c"], Value::Int(1));
    }

    #[test]
    fn arrays_mixed_and_strings() {
        let m = parse_document("xs = [1, 2, 3]\nys = [\"a,b\", \"c\"]").unwrap();
        assert_eq!(m["xs"], Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)]));
        assert_eq!(
            m["ys"],
            Value::Array(vec![Value::Str("a,b".into()), Value::Str("c".into())])
        );
    }

    #[test]
    fn string_escapes() {
        let m = parse_document(r#"s = "a\nb\t\"q\"""#).unwrap();
        assert_eq!(m["s"], Value::Str("a\nb\t\"q\"".into()));
    }

    #[test]
    fn errors_have_line_numbers() {
        assert_eq!(
            parse_document("a = 1\nbad line"),
            Err(ConfigError::Syntax(2, "expected `key = value`".into()))
        );
        assert_eq!(
            parse_document("a = 1\na = 2"),
            Err(ConfigError::DuplicateKey("a".into(), 2))
        );
        assert!(matches!(parse_document("[unterminated"), Err(ConfigError::Syntax(1, _))));
        assert!(matches!(parse_document("x = \"open"), Err(ConfigError::Syntax(1, _))));
        assert!(matches!(parse_document("x = [1, 2"), Err(ConfigError::Syntax(1, _))));
        assert!(matches!(parse_document("x = zzz"), Err(ConfigError::Syntax(1, _))));
    }
}
