//! Command-line argument parsing (hand-rolled; `clap` is not available in
//! the sandbox's vendored crate set).
//!
//! Supports: subcommands, `--flag`, `--key value`, `--key=value`, positional
//! arguments, `-h/--help` text generation, and typed accessors with
//! defaults. The `mfnn` binary defines its subcommands in
//! `rust/src/main.rs`; this module is generic.

use std::collections::BTreeMap;
use thiserror::Error;

/// CLI parse errors.
#[derive(Debug, Error, PartialEq)]
pub enum CliError {
    /// Option is not declared in the spec.
    #[error("unknown option --{0}")]
    UnknownOption(String),
    /// Declared value-taking option used without a value.
    #[error("option --{0} requires a value")]
    MissingValue(String),
    /// Value failed to parse as the requested type.
    #[error("option --{0}: cannot parse {1:?} as {2}")]
    BadValue(String, String, &'static str),
    /// More positional args than declared.
    #[error("unexpected positional argument {0:?}")]
    UnexpectedPositional(String),
    /// Required positional missing.
    #[error("missing required argument <{0}>")]
    MissingPositional(&'static str),
}

/// Whether an option takes a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arity {
    /// Boolean switch.
    Flag,
    /// Takes one value.
    Value,
}

/// One declared option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without the leading `--`.
    pub name: &'static str,
    /// Flag or value-taking.
    pub arity: Arity,
    /// One-line help text.
    pub help: &'static str,
    /// Shown default in help output (informational only).
    pub default: Option<&'static str>,
}

/// A declared positional argument.
#[derive(Debug, Clone)]
pub struct PosSpec {
    /// Name shown as `<name>`.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// If false, may be omitted.
    pub required: bool,
}

/// A parser spec: options + positionals for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    opts: Vec<OptSpec>,
    positionals: Vec<PosSpec>,
}

impl Spec {
    /// Empty spec.
    pub fn new() -> Spec {
        Spec::default()
    }

    /// Declare a boolean switch.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Spec {
        self.opts.push(OptSpec { name, arity: Arity::Flag, help, default: None });
        self
    }

    /// Declare a value-taking option.
    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Spec {
        self.opts.push(OptSpec { name, arity: Arity::Value, help, default });
        self
    }

    /// Declare a positional argument (declared order = consumption order).
    pub fn pos(mut self, name: &'static str, help: &'static str, required: bool) -> Spec {
        self.positionals.push(PosSpec { name, help, required });
        self
    }

    fn find(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Parse an argument list (excluding program/subcommand names).
    pub fn parse<I, S>(&self, args: I) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        let mut it = args.into_iter().map(Into::into).peekable();
        let mut after_separator = false;
        while let Some(arg) = it.next() {
            if after_separator || !arg.starts_with("--") || arg == "-" {
                positionals.push(arg);
                continue;
            }
            if arg == "--" {
                after_separator = true;
                continue;
            }
            let body = &arg[2..];
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            let spec = self.find(&name).ok_or_else(|| CliError::UnknownOption(name.clone()))?;
            match spec.arity {
                Arity::Flag => {
                    if let Some(v) = inline {
                        return Err(CliError::BadValue(name, v, "flag (takes no value)"));
                    }
                    flags.push(name);
                }
                Arity::Value => {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    values.insert(name, v);
                }
            }
        }
        if positionals.len() > self.positionals.len() {
            return Err(CliError::UnexpectedPositional(
                positionals[self.positionals.len()].clone(),
            ));
        }
        for (i, p) in self.positionals.iter().enumerate() {
            if p.required && i >= positionals.len() {
                return Err(CliError::MissingPositional(p.name));
            }
        }
        Ok(Args { values, flags, positionals, pos_spec: self.positionals.clone() })
    }

    /// Render `--help` text for this spec.
    pub fn help(&self, cmd: &str, about: &str) -> String {
        let mut s = format!("{about}\n\nUSAGE: {cmd}");
        for p in &self.positionals {
            if p.required {
                s.push_str(&format!(" <{}>", p.name));
            } else {
                s.push_str(&format!(" [{}]", p.name));
            }
        }
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        s.push('\n');
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for p in &self.positionals {
                s.push_str(&format!("  <{}>  {}\n", p.name, p.help));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let mut left = format!("--{}", o.name);
                if o.arity == Arity::Value {
                    left.push_str(" <v>");
                }
                let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
                s.push_str(&format!("  {left:<24} {}{default}\n", o.help));
            }
        }
        s
    }
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
    pos_spec: Vec<PosSpec>,
}

impl Args {
    /// Was a flag given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw string value of an option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// String value with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Parse an option as `T`, with default when absent.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| {
                CliError::BadValue(name.to_string(), v.to_string(), std::any::type_name::<T>())
            }),
        }
    }

    /// Positional by declared name.
    pub fn positional(&self, name: &str) -> Option<&str> {
        let idx = self.pos_spec.iter().position(|p| p.name == name)?;
        self.positionals.get(idx).map(|s| s.as_str())
    }

    /// All positionals in order.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new()
            .flag("verbose", "more output")
            .opt("steps", "training steps", Some("100"))
            .opt("device", "FPGA part", Some("XC7S75-2"))
            .pos("config", "launcher config path", true)
            .pos("out", "output path", false)
    }

    #[test]
    fn parses_mixed_forms() {
        let a = spec()
            .parse(["--verbose", "cfg.toml", "--steps=250", "--device", "XC7S50-1", "out.txt"])
            .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_or("steps", 0u32).unwrap(), 250);
        assert_eq!(a.get("device"), Some("XC7S50-1"));
        assert_eq!(a.positional("config"), Some("cfg.toml"));
        assert_eq!(a.positional("out"), Some("out.txt"));
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(["cfg.toml"]).unwrap();
        assert!(!a.flag("verbose"));
        assert_eq!(a.parse_or("steps", 100u32).unwrap(), 100);
        assert_eq!(a.positional("out"), None);
    }

    #[test]
    fn errors() {
        assert_eq!(
            spec().parse(["--nope", "cfg"]).unwrap_err(),
            CliError::UnknownOption("nope".into())
        );
        assert_eq!(
            spec().parse(["cfg", "--steps"]).unwrap_err(),
            CliError::MissingValue("steps".into())
        );
        assert_eq!(spec().parse::<_, &str>([]).unwrap_err(), CliError::MissingPositional("config"));
        assert_eq!(
            spec().parse(["a", "b", "c"]).unwrap_err(),
            CliError::UnexpectedPositional("c".into())
        );
        let a = spec().parse(["cfg", "--steps", "abc"]).unwrap();
        assert!(matches!(a.parse_or("steps", 0u32), Err(CliError::BadValue(_, _, _))));
    }

    #[test]
    fn double_dash_stops_option_parsing() {
        let a = spec().parse(["--", "--steps"]).unwrap();
        assert_eq!(a.positional("config"), Some("--steps"));
    }

    #[test]
    fn help_mentions_everything() {
        let h = spec().help("mfnn train", "Train MLPs");
        assert!(h.contains("--steps"));
        assert!(h.contains("<config>"));
        assert!(h.contains("[out]"));
        assert!(h.contains("[default: 100]"));
    }
}
