//! `mfnn` — a reproduction of *Hardware/Software Codesign for Training/Testing
//! Multiple Neural Networks on Multiple FPGAs* (Brosnan Yuen, 2019) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) contains the paper's software/hardware contribution:
//! the **Matrix Assembler** ([`asm`], [`assembler`]), the **Matrix Machine**
//! simulated cycle-accurately ([`hw`]), the analytic performance/cost models
//! ([`perf`]), MLP training lowered onto the vector ISA ([`nn`]), and the
//! **multi-FPGA cluster coordinator** ([`cluster`]). The [`session`] module
//! is the unified front door over all of them: [`Compiler`] produces
//! compile-once [`Artifact`]s and [`Session`] runs them on a single board
//! or a whole cluster with typed tensor handles and one [`enum@Error`].
//! The [`serve`] module is the multi-tenant batched inference serving
//! runtime: many nets, concurrent requests, a dynamic micro-batcher over
//! a forward batch ladder, and a board pool — deterministic and
//! bit-identical to sequential `Session::infer` (`mfnn serve-sim`;
//! DESIGN.md §Serving).
//! The [`runtime`] module loads the JAX/Pallas golden model (AOT-compiled
//! to HLO text by `python/compile/aot.py`) through PJRT and is used as a
//! bit-exact oracle and host baseline. Python never runs at runtime.
//! The [`testkit`] module is the differential-fuzzing and deterministic
//! fault-injection harness that generates scenarios and proves all five
//! simulator fidelity levels agree (`mfnn fuzz`; DESIGN.md §Testing).
//! The [`analysis`] module is the static program checker: lane-granular
//! dataflow, fixed-point interval analysis, ring-FIFO safety proofs,
//! and a hazard oracle over every compiled program (`mfnn lint`;
//! DESIGN.md §Static analysis).
//!
//! See `DESIGN.md` for the system inventory and the experiment index mapping
//! every table/figure of the paper to modules and benches.

pub mod analysis;
pub mod asm;
pub mod assembler;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod fixed;
pub mod hw;
pub mod isa;
pub mod nn;
pub mod perf;
pub mod prop;
pub mod report;
/// PJRT-backed golden-model runtime. Off by default (cargo feature
/// `xla`) so the stock build has no external native dependency; see
/// DESIGN.md §Runtime for how to enable it.
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod session;
pub mod testkit;
pub mod util;

pub use analysis::{CheckLevel, CheckOptions, CheckReport};
pub use serve::{ServeConfig, ServeFaultPlan, Server, SubmitOptions};
pub use cluster::{RecoveryPolicy, TrainCheckpoint};
pub use session::{
    Artifact, CompileOptions, Compiler, Error, Session, Target, TensorHandle, TrainOptions,
};

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
