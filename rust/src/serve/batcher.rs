//! The dynamic micro-batcher: one bounded FIFO queue per registered
//! net, flushed into dispatchable micro-batches on any of three
//! triggers (whichever fires first, all in **simulated** cycles so the
//! whole serving runtime is deterministic):
//!
//! * **fill** — the queue reaches `max_batch` waiting requests;
//! * **wait bound** — the oldest waiting request has waited
//!   `max_wait_cycles` (a partial batch flushes rather than starving);
//! * **SLO urgency** — a queued request's deadline is within
//!   `deadline_slack` cycles: the whole partial tail flushes early, so
//!   the urgent request rides a *smaller* ladder bucket with a faster
//!   plan — the forward-variant ladder used for adaptive routing
//!   (DESIGN.md §Serving, "Degraded mode").
//!
//! Batch splitting reuses [`dataset::chunk_ranges`] — the same chunking
//! rule `Session::evaluate` and the trainer use — so every batched
//! forward path in the codebase cuts batches identically.

use crate::nn::dataset;
use std::collections::VecDeque;

/// A request waiting in a net's queue.
#[derive(Debug, Clone)]
pub struct Pending {
    /// Request id (server-assigned, monotonic).
    pub id: u64,
    /// Quantised input row (`in_dim` lanes).
    pub row: Vec<i16>,
    /// Simulated cycle the request was admitted.
    pub arrival: u64,
    /// Scheduling priority (higher = more important; sheds last).
    pub priority: u8,
    /// Absolute simulated-cycle deadline, if the request carries an SLO
    /// (`None` = best-effort, treated as the latest possible deadline).
    pub deadline: Option<u64>,
}

impl Pending {
    /// The deadline used for ordering decisions: `None` sorts after
    /// every finite deadline (best-effort requests shed first among
    /// equal priorities).
    pub fn effective_deadline(&self) -> u64 {
        self.deadline.unwrap_or(u64::MAX)
    }
}

/// Per-net micro-batcher state.
#[derive(Debug)]
pub struct MicroBatcher {
    max_batch: usize,
    max_wait_cycles: u64,
    cap: usize,
    deadline_slack: u64,
    queue: VecDeque<Pending>,
}

impl MicroBatcher {
    /// New empty batcher. `max_batch` is the fill-flush threshold,
    /// `max_wait_cycles` the wait-bound flush latency, `cap` the
    /// admission-control queue capacity, and `deadline_slack` the SLO
    /// urgency margin: a queued request whose deadline is within
    /// `deadline_slack` cycles forces a partial flush.
    pub fn new(
        max_batch: usize,
        max_wait_cycles: u64,
        cap: usize,
        deadline_slack: u64,
    ) -> MicroBatcher {
        assert!(max_batch >= 1, "max_batch must be positive");
        assert!(cap >= 1, "queue capacity must be positive");
        MicroBatcher { max_batch, max_wait_cycles, cap, deadline_slack, queue: VecDeque::new() }
    }

    /// Requests currently waiting.
    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    /// Iterate the waiting requests in FIFO order (shed-victim scans).
    pub fn iter(&self) -> impl Iterator<Item = &Pending> {
        self.queue.iter()
    }

    /// Remove a specific waiting request by id (load shedding). Returns
    /// the removed request, or `None` when `id` is not queued. Relative
    /// order of the survivors is preserved.
    pub fn remove(&mut self, id: u64) -> Option<Pending> {
        let at = self.queue.iter().position(|p| p.id == id)?;
        self.queue.remove(at)
    }

    /// Admission: enqueue `p`, or refuse with the current depth when the
    /// queue is at capacity (the server turns this into the typed
    /// `Shed` rejection — requests are never silently dropped and the
    /// queue never grows without bound).
    pub fn push(&mut self, p: Pending) -> Result<(), usize> {
        if self.queue.len() >= self.cap {
            return Err(self.queue.len());
        }
        self.queue.push_back(p);
        Ok(())
    }

    /// Simulated cycle at which the queue forces a partial flush
    /// (`None` when the queue is empty): the oldest request's wait
    /// bound, or the earliest SLO-urgency trigger (`deadline -
    /// deadline_slack`) of any queued request, whichever is sooner.
    /// This is the batcher's contribution to the server's next-event
    /// computation.
    pub fn deadline(&self) -> Option<u64> {
        let wait = self.queue.front().map(|p| p.arrival + self.max_wait_cycles)?;
        let urgency = self
            .queue
            .iter()
            .filter_map(|p| p.deadline)
            .map(|d| d.saturating_sub(self.deadline_slack))
            .min();
        Some(urgency.map_or(wait, |u| u.min(wait)))
    }

    /// Pop every batch that is due at simulated cycle `now`: full
    /// `max_batch` groups always flush; the partial tail flushes only
    /// when the wait bound or an SLO-urgency trigger has passed (the
    /// early partial flush is what routes deadline-at-risk requests
    /// onto a smaller, faster ladder bucket). Returned batches preserve
    /// FIFO order and are split by [`dataset::chunk_ranges`].
    pub fn take_ready(&mut self, now: u64) -> Vec<Vec<Pending>> {
        let full = self.queue.len() - self.queue.len() % self.max_batch;
        let take = if self.deadline().is_some_and(|d| d <= now) {
            self.queue.len()
        } else {
            full
        };
        if take == 0 {
            return Vec::new();
        }
        let mut rows: Vec<Pending> = self.queue.drain(..take).collect();
        let mut out = Vec::new();
        for r in dataset::chunk_ranges(take, self.max_batch) {
            out.push(rows.drain(..r.len()).collect());
        }
        out
    }
}

/// The smallest ladder bucket that fits `rows` requests (`None` when
/// `rows` exceeds every bucket — never happens for server batches, whose
/// size is capped at `max_batch`, the ladder's top bucket).
pub fn bucket_for(rows: usize, ladder: &[usize]) -> Option<usize> {
    ladder.iter().copied().filter(|&b| b >= rows).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(id: u64, arrival: u64) -> Pending {
        Pending { id, row: vec![0; 2], arrival, priority: 0, deadline: None }
    }

    fn slo(id: u64, arrival: u64, deadline: u64) -> Pending {
        Pending { id, row: vec![0; 2], arrival, priority: 0, deadline: Some(deadline) }
    }

    #[test]
    fn fill_flush_pops_full_batches_in_fifo_order() {
        let mut b = MicroBatcher::new(4, 100, 64, 0);
        for i in 0..9 {
            b.push(p(i, 0)).unwrap();
        }
        // two full batches flush immediately; the 1-row tail waits
        let ready = b.take_ready(0);
        assert_eq!(ready.len(), 2);
        assert_eq!(ready[0].iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(ready[1].iter().map(|x| x.id).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        assert_eq!(b.depth(), 1);
        // before the deadline nothing more flushes…
        assert!(b.take_ready(99).is_empty());
        // …at the deadline the partial tail flushes
        let tail = b.take_ready(100);
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0][0].id, 8);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn deadline_tracks_the_oldest_request() {
        let mut b = MicroBatcher::new(8, 10, 64, 0);
        assert_eq!(b.deadline(), None);
        b.push(p(0, 5)).unwrap();
        b.push(p(1, 9)).unwrap();
        assert_eq!(b.deadline(), Some(15));
        assert_eq!(b.take_ready(15).len(), 1);
        assert_eq!(b.deadline(), None);
    }

    #[test]
    fn slo_urgency_pulls_the_flush_forward() {
        let mut b = MicroBatcher::new(8, 1000, 64, 16);
        b.push(p(0, 0)).unwrap();
        // best-effort alone: wait bound governs
        assert_eq!(b.deadline(), Some(1000));
        // an SLO request whose deadline-minus-slack beats the wait bound
        b.push(slo(1, 4, 100)).unwrap();
        assert_eq!(b.deadline(), Some(84));
        // urgency flushes the whole partial tail early, onto a smaller bucket
        assert!(b.take_ready(83).is_empty());
        let ready = b.take_ready(84);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].len(), 2, "urgent flush takes the whole partial tail");
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn lax_deadlines_do_not_beat_the_wait_bound() {
        let mut b = MicroBatcher::new(8, 10, 64, 2);
        b.push(slo(0, 5, 500)).unwrap();
        assert_eq!(b.deadline(), Some(15), "wait bound still governs lax SLOs");
    }

    #[test]
    fn remove_sheds_by_id_and_preserves_order() {
        let mut b = MicroBatcher::new(8, 10, 64, 0);
        for i in 0..4 {
            b.push(p(i, 0)).unwrap();
        }
        let victim = b.remove(2).expect("queued");
        assert_eq!(victim.id, 2);
        assert_eq!(b.remove(2).map(|p| p.id), None, "already removed");
        assert_eq!(b.iter().map(|p| p.id).collect::<Vec<_>>(), vec![0, 1, 3]);
    }

    #[test]
    fn admission_control_refuses_at_capacity() {
        let mut b = MicroBatcher::new(8, 10, 2, 0);
        b.push(p(0, 0)).unwrap();
        b.push(p(1, 0)).unwrap();
        assert_eq!(b.push(p(2, 0)), Err(2));
        assert_eq!(b.depth(), 2, "refused request must not be enqueued");
    }

    #[test]
    fn bucket_for_picks_the_smallest_fitting_bucket() {
        let ladder = [1usize, 2, 4, 8];
        assert_eq!(bucket_for(1, &ladder), Some(1));
        assert_eq!(bucket_for(3, &ladder), Some(4));
        assert_eq!(bucket_for(8, &ladder), Some(8));
        assert_eq!(bucket_for(9, &ladder), None);
    }

    #[test]
    fn zero_wait_flushes_any_nonempty_queue() {
        let mut b = MicroBatcher::new(8, 0, 64, 0);
        b.push(p(0, 3)).unwrap();
        let ready = b.take_ready(3);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].len(), 1);
    }
}
