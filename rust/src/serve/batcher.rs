//! The dynamic micro-batcher: one bounded FIFO queue per registered
//! net, flushed into dispatchable micro-batches on either of two
//! triggers (whichever fires first, both in **simulated** cycles so the
//! whole serving runtime is deterministic):
//!
//! * **fill** — the queue reaches `max_batch` waiting requests;
//! * **deadline** — the oldest waiting request has waited
//!   `max_wait_cycles` (a partial batch flushes rather than starving).
//!
//! Batch splitting reuses [`dataset::chunk_ranges`] — the same chunking
//! rule `Session::evaluate` and the trainer use — so every batched
//! forward path in the codebase cuts batches identically.

use crate::nn::dataset;
use std::collections::VecDeque;

/// A request waiting in a net's queue.
#[derive(Debug, Clone)]
pub struct Pending {
    /// Request id (server-assigned, monotonic).
    pub id: u64,
    /// Quantised input row (`in_dim` lanes).
    pub row: Vec<i16>,
    /// Simulated cycle the request was admitted.
    pub arrival: u64,
}

/// Per-net micro-batcher state.
#[derive(Debug)]
pub struct MicroBatcher {
    max_batch: usize,
    max_wait_cycles: u64,
    cap: usize,
    queue: VecDeque<Pending>,
}

impl MicroBatcher {
    /// New empty batcher. `max_batch` is the fill-flush threshold,
    /// `max_wait_cycles` the deadline-flush latency bound, `cap` the
    /// admission-control queue capacity.
    pub fn new(max_batch: usize, max_wait_cycles: u64, cap: usize) -> MicroBatcher {
        assert!(max_batch >= 1, "max_batch must be positive");
        assert!(cap >= 1, "queue capacity must be positive");
        MicroBatcher { max_batch, max_wait_cycles, cap, queue: VecDeque::new() }
    }

    /// Requests currently waiting.
    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    /// Admission: enqueue `p`, or refuse with the current depth when the
    /// queue is at capacity (the server turns this into the typed
    /// `Overloaded` rejection — requests are never silently dropped and
    /// the queue never grows without bound).
    pub fn push(&mut self, p: Pending) -> Result<(), usize> {
        if self.queue.len() >= self.cap {
            return Err(self.queue.len());
        }
        self.queue.push_back(p);
        Ok(())
    }

    /// Simulated cycle at which the oldest waiting request forces a
    /// deadline flush (`None` when the queue is empty). This is the
    /// batcher's contribution to the server's next-event computation.
    pub fn deadline(&self) -> Option<u64> {
        self.queue.front().map(|p| p.arrival + self.max_wait_cycles)
    }

    /// Pop every batch that is due at simulated cycle `now`: full
    /// `max_batch` groups always flush; the partial tail flushes only
    /// when its deadline has passed. Returned batches preserve FIFO
    /// order and are split by [`dataset::chunk_ranges`].
    pub fn take_ready(&mut self, now: u64) -> Vec<Vec<Pending>> {
        let full = self.queue.len() - self.queue.len() % self.max_batch;
        let take = if self.deadline().is_some_and(|d| d <= now) {
            self.queue.len()
        } else {
            full
        };
        if take == 0 {
            return Vec::new();
        }
        let mut rows: Vec<Pending> = self.queue.drain(..take).collect();
        let mut out = Vec::new();
        for r in dataset::chunk_ranges(take, self.max_batch) {
            out.push(rows.drain(..r.len()).collect());
        }
        out
    }
}

/// The smallest ladder bucket that fits `rows` requests (`None` when
/// `rows` exceeds every bucket — never happens for server batches, whose
/// size is capped at `max_batch`, the ladder's top bucket).
pub fn bucket_for(rows: usize, ladder: &[usize]) -> Option<usize> {
    ladder.iter().copied().filter(|&b| b >= rows).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(id: u64, arrival: u64) -> Pending {
        Pending { id, row: vec![0; 2], arrival }
    }

    #[test]
    fn fill_flush_pops_full_batches_in_fifo_order() {
        let mut b = MicroBatcher::new(4, 100, 64);
        for i in 0..9 {
            b.push(p(i, 0)).unwrap();
        }
        // two full batches flush immediately; the 1-row tail waits
        let ready = b.take_ready(0);
        assert_eq!(ready.len(), 2);
        assert_eq!(ready[0].iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(ready[1].iter().map(|x| x.id).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        assert_eq!(b.depth(), 1);
        // before the deadline nothing more flushes…
        assert!(b.take_ready(99).is_empty());
        // …at the deadline the partial tail flushes
        let tail = b.take_ready(100);
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0][0].id, 8);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn deadline_tracks_the_oldest_request() {
        let mut b = MicroBatcher::new(8, 10, 64);
        assert_eq!(b.deadline(), None);
        b.push(p(0, 5)).unwrap();
        b.push(p(1, 9)).unwrap();
        assert_eq!(b.deadline(), Some(15));
        assert_eq!(b.take_ready(15).len(), 1);
        assert_eq!(b.deadline(), None);
    }

    #[test]
    fn admission_control_refuses_at_capacity() {
        let mut b = MicroBatcher::new(8, 10, 2);
        b.push(p(0, 0)).unwrap();
        b.push(p(1, 0)).unwrap();
        assert_eq!(b.push(p(2, 0)), Err(2));
        assert_eq!(b.depth(), 2, "refused request must not be enqueued");
    }

    #[test]
    fn bucket_for_picks_the_smallest_fitting_bucket() {
        let ladder = [1usize, 2, 4, 8];
        assert_eq!(bucket_for(1, &ladder), Some(1));
        assert_eq!(bucket_for(3, &ladder), Some(4));
        assert_eq!(bucket_for(8, &ladder), Some(8));
        assert_eq!(bucket_for(9, &ladder), None);
    }

    #[test]
    fn zero_wait_flushes_any_nonempty_queue() {
        let mut b = MicroBatcher::new(8, 0, 64);
        b.push(p(0, 3)).unwrap();
        let ready = b.take_ready(3);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].len(), 1);
    }
}
