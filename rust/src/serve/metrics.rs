//! Per-net and per-board serving metrics, all in **simulated** cycles:
//! queue depth, batch-fill ratio, p50/p99 request latency, and
//! throughput derived from the simulated makespan. Snapshots render as a
//! table (`mfnn serve-sim`) and serialise to deterministic JSON (the CI
//! artifact and the `BENCH_serving.json` notes source).

use crate::bench::json_str;
use crate::hw::FpgaDevice;
use crate::report::{f as fmt_f, Table};

/// Percentile of an already-sorted sample (`0` when empty): the value
/// at rank `⌊p/100 · (n−1)⌋`, so `p50` of an even-sized sample is the
/// lower median (never above it).
fn sorted_percentile(s: &[u64], p: f64) -> u64 {
    if s.is_empty() {
        return 0;
    }
    let idx = ((p / 100.0) * (s.len() as f64 - 1.0)).floor() as usize;
    s[idx.min(s.len() - 1)]
}

/// Nearest-rank percentile of `xs` (`0` when empty); sorts a copy.
/// Report rendering uses [`NetMetrics::latency_quantiles`] instead,
/// which sorts once for all the quantiles it reads.
pub fn percentile(xs: &[u64], p: f64) -> u64 {
    let mut s = xs.to_vec();
    s.sort_unstable();
    sorted_percentile(&s, p)
}

/// Per-net serving counters and latency distribution.
#[derive(Debug, Clone, Default)]
pub struct NetMetrics {
    /// Net name (artifact name).
    pub name: String,
    /// Requests admitted.
    pub submitted: u64,
    /// Requests completed (outputs delivered).
    pub completed: u64,
    /// Requests refused at submit time (typed `Shed` returned to the
    /// caller, or a deadline already in the past).
    pub rejected: u64,
    /// Admitted requests dropped by load shedding or the hedged-retry
    /// budget (each leaves a typed `DroppedRequest` record).
    pub shed: u64,
    /// Admitted requests dropped because their deadline passed before
    /// their micro-batch dispatched.
    pub expired: u64,
    /// Completed requests whose output was delivered after their
    /// deadline (SLO miss, but the answer was still produced).
    pub late: u64,
    /// Hedged micro-batch re-dispatches after a detected board fault.
    pub retries: u64,
    /// Micro-batches dispatched.
    pub batches: u64,
    /// Real request rows dispatched.
    pub batch_rows: u64,
    /// Bucket slots dispatched (real rows + zero padding).
    pub bucket_rows: u64,
    /// High-water queue depth.
    pub max_queue_depth: usize,
    /// Per-request simulated-cycle latencies (admission → completion).
    pub(crate) latencies: Vec<u64>,
}

impl NetMetrics {
    /// Batch-fill ratio: real rows over dispatched bucket slots
    /// (`1.0` = every dispatched batch exactly filled its bucket).
    pub fn batch_fill(&self) -> f64 {
        if self.bucket_rows == 0 {
            0.0
        } else {
            self.batch_rows as f64 / self.bucket_rows as f64
        }
    }

    /// Median request latency in simulated cycles.
    pub fn latency_p50(&self) -> u64 {
        self.latency_quantiles().0
    }

    /// 99th-percentile request latency in simulated cycles.
    pub fn latency_p99(&self) -> u64 {
        self.latency_quantiles().1
    }

    /// `(p50, p99)` request latency in simulated cycles from **one**
    /// sorted snapshot of the samples (rendering reads both, so this
    /// halves the clone+sort work per report).
    pub fn latency_quantiles(&self) -> (u64, u64) {
        let mut s = self.latencies.clone();
        s.sort_unstable();
        (sorted_percentile(&s, 50.0), sorted_percentile(&s, 99.0))
    }
}

/// Per-board serving counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct BoardMetrics {
    /// Micro-batches this board executed.
    pub batches: u64,
    /// Simulated cycles this board spent computing.
    pub busy_cycles: u64,
    /// Detected faults (corruptions + watchdog stalls) charged to this
    /// board.
    pub strikes: u64,
    /// Times the board crossed the strike threshold and sat out a
    /// quarantine.
    pub quarantines: u64,
    /// True once the board is dead — evicted
    /// ([`crate::serve::Server::evict_board`]) or killed by the fault
    /// plan.
    pub evicted: bool,
}

/// A point-in-time snapshot of a server's serving metrics.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Board part the pool simulates.
    pub device: FpgaDevice,
    /// Per-board counters (index = board id).
    pub boards: Vec<BoardMetrics>,
    /// Per-net counters (index = net id).
    pub nets: Vec<NetMetrics>,
    /// Simulated cycle at which the last dispatched batch completes.
    pub makespan_cycles: u64,
}

impl ServeReport {
    /// Requests admitted across all nets.
    pub fn total_submitted(&self) -> u64 {
        self.nets.iter().map(|n| n.submitted).sum()
    }

    /// Requests completed across all nets.
    pub fn total_completed(&self) -> u64 {
        self.nets.iter().map(|n| n.completed).sum()
    }

    /// Requests refused across all nets.
    pub fn total_rejected(&self) -> u64 {
        self.nets.iter().map(|n| n.rejected).sum()
    }

    /// Admitted requests shed across all nets (load shedding + retry
    /// budget).
    pub fn total_shed(&self) -> u64 {
        self.nets.iter().map(|n| n.shed).sum()
    }

    /// Admitted requests expired (deadline passed undispatched) across
    /// all nets.
    pub fn total_expired(&self) -> u64 {
        self.nets.iter().map(|n| n.expired).sum()
    }

    /// Simulated makespan in seconds on the pool's device.
    pub fn makespan_s(&self) -> f64 {
        self.device.seconds(self.makespan_cycles)
    }

    /// Completed requests per **simulated** second — the throughput
    /// number the serving bench compares across pool/batch
    /// configurations.
    pub fn requests_per_sim_s(&self) -> f64 {
        self.total_completed() as f64 / self.makespan_s().max(1e-30)
    }

    /// Simulated cycles per completed request (makespan amortised).
    pub fn cycles_per_request(&self) -> f64 {
        self.makespan_cycles as f64 / self.total_completed().max(1) as f64
    }

    /// The latency/throughput table `mfnn serve-sim` prints.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "net", "submitted", "done", "rejected", "shed", "expired", "late", "retries",
            "batches", "fill", "p50 (cyc)", "p99 (cyc)", "max depth",
        ])
        .with_title(format!(
            "serving: {} board(s) ({}), makespan {:.3} ms simulated, {:.0} req/s simulated",
            self.boards.len(),
            self.device.part.name,
            self.makespan_s() * 1e3,
            self.requests_per_sim_s(),
        ))
        .numeric();
        for n in &self.nets {
            let (p50, p99) = n.latency_quantiles();
            t.row(vec![
                n.name.clone(),
                n.submitted.to_string(),
                n.completed.to_string(),
                n.rejected.to_string(),
                n.shed.to_string(),
                n.expired.to_string(),
                n.late.to_string(),
                n.retries.to_string(),
                n.batches.to_string(),
                fmt_f(n.batch_fill(), 3),
                p50.to_string(),
                p99.to_string(),
                n.max_queue_depth.to_string(),
            ]);
        }
        let mut s = t.render();
        for (b, m) in self.boards.iter().enumerate() {
            let health = if m.evicted {
                " [dead]".to_string()
            } else if m.strikes > 0 || m.quarantines > 0 {
                format!(" [{} strike(s), {} quarantine(s)]", m.strikes, m.quarantines)
            } else {
                String::new()
            };
            s.push_str(&format!(
                "board {b}: {} batch(es), {} busy cycles ({:.1}% of makespan){}\n",
                m.batches,
                m.busy_cycles,
                100.0 * m.busy_cycles as f64 / self.makespan_cycles.max(1) as f64,
                health,
            ));
        }
        s
    }

    /// Deterministic JSON snapshot (CI artifact; two identical-seed runs
    /// must serialise identically — `mfnn serve-sim --check-determinism`
    /// asserts exactly that).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"device\": {},\n", json_str(self.device.part.name)));
        s.push_str(&format!("  \"boards\": {},\n", self.boards.len()));
        s.push_str(&format!("  \"makespan_cycles\": {},\n", self.makespan_cycles));
        s.push_str(&format!("  \"makespan_s\": {:.9},\n", self.makespan_s()));
        s.push_str(&format!(
            "  \"requests_per_sim_s\": {:.3},\n",
            self.requests_per_sim_s()
        ));
        s.push_str(&format!("  \"cycles_per_request\": {:.3},\n", self.cycles_per_request()));
        s.push_str(&format!("  \"submitted\": {},\n", self.total_submitted()));
        s.push_str(&format!("  \"completed\": {},\n", self.total_completed()));
        s.push_str(&format!("  \"rejected\": {},\n", self.total_rejected()));
        s.push_str(&format!("  \"shed\": {},\n", self.total_shed()));
        s.push_str(&format!("  \"expired\": {},\n", self.total_expired()));
        s.push_str("  \"board_metrics\": [\n");
        for (i, b) in self.boards.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"batches\": {}, \"busy_cycles\": {}, \"strikes\": {}, \
                 \"quarantines\": {}, \"evicted\": {}}}{}\n",
                b.batches,
                b.busy_cycles,
                b.strikes,
                b.quarantines,
                b.evicted,
                if i + 1 == self.boards.len() { "" } else { "," },
            ));
        }
        s.push_str("  ],\n  \"nets\": [\n");
        for (i, n) in self.nets.iter().enumerate() {
            let (p50, p99) = n.latency_quantiles();
            s.push_str(&format!(
                "    {{\"name\": {}, \"submitted\": {}, \"completed\": {}, \
                 \"rejected\": {}, \"shed\": {}, \"expired\": {}, \"late\": {}, \
                 \"retries\": {}, \"batches\": {}, \"batch_fill\": {:.4}, \
                 \"p50_cycles\": {}, \"p99_cycles\": {}, \"max_queue_depth\": {}}}{}\n",
                json_str(&n.name),
                n.submitted,
                n.completed,
                n.rejected,
                n.shed,
                n.expired,
                n.late,
                n.retries,
                n.batches,
                n.batch_fill(),
                p50,
                p99,
                n.max_queue_depth,
                if i + 1 == self.nets.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0);
        assert_eq!(percentile(&[7], 99.0), 7);
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&xs, 50.0), 50);
        assert_eq!(percentile(&xs, 99.0), 99);
        assert_eq!(percentile(&xs, 100.0), 100);
        // unsorted input is handled
        assert_eq!(percentile(&[9, 1, 5], 50.0), 5);
    }

    #[test]
    fn report_aggregates_and_serialises() {
        let report = ServeReport {
            device: FpgaDevice::selected(),
            boards: vec![BoardMetrics {
                batches: 2,
                busy_cycles: 100,
                strikes: 1,
                quarantines: 0,
                evicted: false,
            }],
            nets: vec![NetMetrics {
                name: "a".into(),
                submitted: 4,
                completed: 4,
                rejected: 1,
                shed: 2,
                expired: 1,
                late: 1,
                retries: 1,
                batches: 2,
                batch_rows: 4,
                bucket_rows: 8,
                max_queue_depth: 3,
                latencies: vec![10, 20, 30, 40],
            }],
            makespan_cycles: 200,
        };
        assert_eq!(report.total_submitted(), 4);
        assert_eq!(report.total_rejected(), 1);
        assert_eq!(report.total_shed(), 2);
        assert_eq!(report.total_expired(), 1);
        // one sorted snapshot serves both quantiles (lower-rank rule)
        assert_eq!(report.nets[0].latency_quantiles(), (20, 30));
        assert_eq!(report.nets[0].latency_p50(), 20);
        assert!((report.nets[0].batch_fill() - 0.5).abs() < 1e-12);
        assert!(report.requests_per_sim_s() > 0.0);
        let json = report.to_json();
        assert!(json.contains("\"completed\": 4"), "{json}");
        assert!(json.contains("\"batch_fill\": 0.5000"), "{json}");
        assert!(json.contains("\"shed\": 2"), "{json}");
        assert!(json.contains("\"strikes\": 1"), "{json}");
        let rendered = report.render();
        assert!(rendered.contains("serving: 1 board(s)"), "{rendered}");
        assert!(rendered.contains("1 strike(s)"), "{rendered}");
    }

    #[test]
    fn registered_but_idle_net_reports_zero_quantiles() {
        // A net that never received a request has an empty latency
        // sample; the report must render p50/p99 as 0, not panic.
        let idle = NetMetrics { name: "idle".into(), ..NetMetrics::default() };
        assert_eq!(idle.latency_quantiles(), (0, 0));
        let report = ServeReport {
            device: FpgaDevice::selected(),
            boards: vec![BoardMetrics::default()],
            nets: vec![idle],
            makespan_cycles: 0,
        };
        let json = report.to_json();
        assert!(json.contains("\"p50_cycles\": 0"), "{json}");
        assert!(json.contains("\"p99_cycles\": 0"), "{json}");
        assert!(report.render().contains("idle"));
    }
}
