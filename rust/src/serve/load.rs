//! Synthetic open-loop load generation for `mfnn serve-sim` and the
//! serving bench: a seeded arrival process (uniform inter-arrival gaps
//! with the requested mean, in simulated cycles), a uniform net mix, and
//! random quantised input rows. Everything derives from one seed, so the
//! same seed always produces the same workload — the determinism the
//! serve-sim acceptance check relies on.

use super::server::SubmitOptions;
use crate::fixed::FixedSpec;
use crate::nn::mlp::MlpSpec;
use crate::util::Rng;

/// Seed salt for the SLO annotation stream, so [`slo_open_loop`]'s
/// arrival process stays bit-compatible with [`open_loop`].
const SALT_SLO: u64 = 0xD1B54A32D192ED03;

/// One generated request: which net, when (simulated cycle), and the
/// quantised input row.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthRequest {
    /// Target net (index into the server's registration order).
    pub net: usize,
    /// Arrival cycle (non-decreasing across the returned sequence).
    pub at: u64,
    /// Quantised input row (`in_dims[net]` lanes, values in `[-1, 1]`).
    pub row: Vec<i16>,
}

/// Generate `requests` open-loop requests against nets with the given
/// input dimensions. `mean_gap_cycles` is the mean inter-arrival gap
/// (gaps are uniform over `0..=2·mean`, so the process neither bursts
/// unboundedly nor locks to a fixed cadence).
pub fn open_loop(
    requests: usize,
    seed: u64,
    mean_gap_cycles: u64,
    in_dims: &[usize],
    fixed: FixedSpec,
) -> Vec<SynthRequest> {
    assert!(!in_dims.is_empty(), "open_loop needs at least one net");
    let mut r = Rng::new(seed);
    let mut at = 0u64;
    (0..requests)
        .map(|_| {
            at += r.gen_range(2 * mean_gap_cycles + 1);
            let net = r.gen_range(in_dims.len() as u64) as usize;
            let row = (0..in_dims[net])
                .map(|_| fixed.from_f64(r.gen_f64() * 2.0 - 1.0))
                .collect();
            SynthRequest { net, at, row }
        })
        .collect()
}

/// One generated SLO-annotated request: an [`open_loop`] arrival plus
/// scheduling priority and an optional absolute deadline, for
/// [`crate::serve::Server::submit_with`].
#[derive(Debug, Clone, PartialEq)]
pub struct SloRequest {
    /// Target net (index into the server's registration order).
    pub net: usize,
    /// Arrival cycle (non-decreasing across the returned sequence).
    pub at: u64,
    /// Quantised input row (`in_dims[net]` lanes, values in `[-1, 1]`).
    pub row: Vec<i16>,
    /// Scheduling priority in `0..3` (higher sheds last).
    pub priority: u8,
    /// Absolute deadline cycle (about half the requests carry one).
    pub deadline: Option<u64>,
}

impl SloRequest {
    /// This request's [`SubmitOptions`].
    pub fn options(&self) -> SubmitOptions {
        SubmitOptions { priority: self.priority, deadline: self.deadline }
    }
}

/// Generate `requests` SLO-annotated open-loop requests: the arrivals,
/// net mix, and rows are **exactly** [`open_loop`]'s (same seed ⇒ same
/// base stream, bit for bit), and a second, salted seed stream assigns
/// each request a priority in `0..3` and — with probability ½ — an
/// absolute deadline `at + 256 + uniform(0..2048)` cycles out. This is
/// the workload behind `mfnn serve-sim --chaos` and the `serve-chaos`
/// fuzz family.
pub fn slo_open_loop(
    requests: usize,
    seed: u64,
    mean_gap_cycles: u64,
    in_dims: &[usize],
    fixed: FixedSpec,
) -> Vec<SloRequest> {
    let base = open_loop(requests, seed, mean_gap_cycles, in_dims, fixed);
    let mut r = Rng::new(seed ^ SALT_SLO);
    base.into_iter()
        .map(|q| {
            let priority = r.gen_range(3) as u8;
            let deadline =
                if r.gen_bool(0.5) { Some(q.at + 256 + r.gen_range(2048)) } else { None };
            SloRequest { net: q.net, at: q.at, row: q.row, priority, deadline }
        })
        .collect()
}

/// Seeded random quantised parameters for `spec`: weights uniform in
/// `±1/fan_in`, biases in `±0.25`, quantised in the spec's fixed
/// format — the one parameter generator the serve-sim CLI, the serving
/// bench, and the serving tests share (one distribution, one
/// quantisation rule, everywhere).
pub fn seeded_params(spec: &MlpSpec, seed: u64) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
    let f = spec.fixed;
    let mut r = Rng::new(seed);
    let mut w: Vec<Vec<i16>> = Vec::with_capacity(spec.layers.len());
    let mut b: Vec<Vec<i16>> = Vec::with_capacity(spec.layers.len());
    for layer in &spec.layers {
        let scale = 1.0 / layer.inputs as f64;
        w.push(
            (0..layer.inputs * layer.outputs)
                .map(|_| f.from_f64((r.gen_f64() * 2.0 - 1.0) * scale))
                .collect(),
        );
        b.push(
            (0..layer.outputs)
                .map(|_| f.from_f64((r.gen_f64() * 2.0 - 1.0) * 0.25))
                .collect(),
        );
    }
    (w, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_params_are_deterministic_and_shaped() {
        use crate::nn::lut::ActKind;
        use crate::nn::mlp::LutParams;
        let f = FixedSpec::q(10).saturating();
        let spec = MlpSpec::from_dims(
            "p",
            &[3, 6, 2],
            ActKind::Relu,
            ActKind::Identity,
            f,
            LutParams::training(f),
        )
        .unwrap();
        let (w, b) = seeded_params(&spec, 9);
        assert_eq!(seeded_params(&spec, 9), (w.clone(), b.clone()));
        assert_ne!(seeded_params(&spec, 10), (w.clone(), b.clone()));
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].len(), 3 * 6);
        assert_eq!(w[1].len(), 6 * 2);
        assert_eq!(b[0].len(), 6);
        assert_eq!(b[1].len(), 2);
    }

    #[test]
    fn workload_is_deterministic_and_well_formed() {
        let f = FixedSpec::q(10).saturating();
        let a = open_loop(64, 7, 5, &[4, 6, 3], f);
        let b = open_loop(64, 7, 5, &[4, 6, 3], f);
        assert_eq!(a, b, "same seed must regenerate the same workload");
        assert_eq!(a.len(), 64);
        let mut last = 0u64;
        let mut hit = [false; 3];
        for q in &a {
            assert!(q.at >= last, "arrivals must be non-decreasing");
            last = q.at;
            assert_eq!(q.row.len(), [4usize, 6, 3][q.net]);
            hit[q.net] = true;
        }
        assert!(hit.iter().all(|&h| h), "64 requests should hit all 3 nets");
        assert_ne!(a, open_loop(64, 8, 5, &[4, 6, 3], f), "seed must matter");
    }

    #[test]
    fn slo_workload_rides_the_open_loop_stream_unchanged() {
        let f = FixedSpec::q(10).saturating();
        let slo = slo_open_loop(64, 7, 5, &[4, 6, 3], f);
        assert_eq!(slo, slo_open_loop(64, 7, 5, &[4, 6, 3], f), "seeded");
        // stripping the SLO annotations recovers open_loop bit for bit
        let base = open_loop(64, 7, 5, &[4, 6, 3], f);
        for (s, b) in slo.iter().zip(&base) {
            assert_eq!((s.net, s.at, &s.row), (b.net, b.at, &b.row));
            assert!(s.priority < 3);
            if let Some(d) = s.deadline {
                assert!(d >= s.at + 256, "deadlines leave a feasible window");
            }
        }
        assert!(slo.iter().any(|s| s.deadline.is_some()), "some requests carry SLOs");
        assert!(slo.iter().any(|s| s.deadline.is_none()), "some are best-effort");
        assert!(slo.iter().any(|s| s.priority > 0), "priorities vary");
        let opts = slo[0].options();
        assert_eq!(opts.priority, slo[0].priority);
        assert_eq!(opts.deadline, slo[0].deadline);
    }

    #[test]
    fn zero_gap_is_a_burst_at_cycle_zero() {
        let f = FixedSpec::q(10);
        let a = open_loop(8, 1, 0, &[2], f);
        assert!(a.iter().all(|q| q.at == 0));
    }
}
