//! The multi-tenant serving [`Server`]: per-net request queues, the
//! dynamic micro-batcher, bucket selection from the forward batch
//! ladder, and board-pool placement — driven as a **discrete-event
//! simulation** over the machine cycle model, so every run is
//! deterministic (same seed ⇒ same outputs, same metrics, bit for bit).
//!
//! ```text
//!   submit_at(cycle, net, row)
//!        │  admission control (typed Overloaded beyond queue_cap)
//!        ▼
//!   per-net FIFO queue ──▶ micro-batcher (flush on max_batch │ max_wait)
//!        │                        │ bucket = smallest ladder plan ≥ rows
//!        ▼                        ▼
//!   ready batches ──▶ board pool (earliest-free board; FIFO batches)
//!                          │ ExecPlan::run_forward on the (net, bucket)
//!                          │ engine; service time = RunStats.cycles
//!                          ▼
//!                     completions (outputs + latency), metrics
//! ```
//!
//! **No-hang contract** (the serving twin of the cluster's
//! "leader-never-hangs"): admission is bounded, every formed batch
//! dispatches at a finite board-free time, and [`Server::drain`]
//! terminates after finitely many events — an overload surfaces as a
//! typed [`ServeError::Overloaded`] rejection at submit time, never as a
//! stuck queue.

use super::batcher::{bucket_for, MicroBatcher, Pending};
use super::metrics::{BoardMetrics, NetMetrics, ServeReport};
use crate::hw::{ExecPlan, FpgaDevice, PlanState, COLUMN_LEN};
use crate::nn::dataset;
use crate::nn::lowering::forward_buckets;
use crate::session::artifact::ForwardVariant;
use crate::session::Artifact;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use thiserror::Error;

/// Index of a registered net (registration order).
pub type NetId = usize;

/// Server-assigned request id (monotonic across all nets).
pub type RequestId = u64;

/// Serving runtime errors — all typed; in particular overload is a
/// first-class rejection, not a hang or a silent drop.
#[derive(Debug, Error)]
pub enum ServeError {
    /// Unknown FPGA part name.
    #[error("unknown FPGA part {0:?}")]
    UnknownDevice(String),
    /// Invalid server configuration.
    #[error("bad serve config: {0}")]
    Config(String),
    /// Net id was never registered.
    #[error("unknown net id {0}")]
    UnknownNet(NetId),
    /// Artifact cannot serve (raw program, missing network structure).
    #[error("artifact {net:?} is not servable: {why}")]
    NotServable {
        /// Artifact name.
        net: String,
        /// Why it cannot serve.
        why: String,
    },
    /// Registered parameters disagree with the net's layer shapes.
    #[error("net {net:?}: layer {layer} {what} expect {want} lanes, got {got}")]
    BadParams {
        /// Artifact name.
        net: String,
        /// Layer index.
        layer: usize,
        /// `"weights"` or `"biases"`.
        what: &'static str,
        /// Expected lane count.
        want: usize,
        /// Provided lane count.
        got: usize,
    },
    /// Request row has the wrong lane count for the target net.
    #[error("net {net}: request row has {got} lanes, expected {want}")]
    BadRow {
        /// Target net id.
        net: NetId,
        /// Expected lane count (`input_dim`).
        want: usize,
        /// Provided lane count.
        got: usize,
    },
    /// Admission control refused the request: the net's backlog —
    /// requests admitted but not yet dispatched to a board, whether
    /// still queued or already formed into waiting batches — is at
    /// capacity. The caller decides whether to retry later, shed load,
    /// or fail.
    #[error("net {net} overloaded: backlog {depth} at capacity {cap}; retry later")]
    Overloaded {
        /// Target net id.
        net: NetId,
        /// Backlog (undispatched admitted requests) at rejection time.
        depth: usize,
        /// Configured capacity.
        cap: usize,
    },
    /// Every board of the pool has been evicted: nothing can serve the
    /// backlog (or admit new requests). Unlike a transient
    /// [`ServeError::Overloaded`] this is terminal for the server.
    #[error("all {boards} board(s) evicted; cannot serve")]
    NoBoards {
        /// Pool size (all evicted).
        boards: usize,
    },
    /// Submissions must carry a non-decreasing simulated clock.
    #[error("simulated clock must be monotonic: submit at cycle {at} before now {now}")]
    ClockSkew {
        /// Requested submission cycle.
        at: u64,
        /// Server's current simulated cycle.
        now: u64,
    },
    /// Lowering a forward-ladder bucket failed (unreachable for
    /// configurations that pass [`Server::open`] validation).
    #[error("forward ladder compile failed: {0}")]
    Compile(String),
}

/// Serving runtime configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Boards in the pool.
    pub boards: usize,
    /// Board part name (Table 8 catalog).
    pub device: String,
    /// Micro-batcher fill-flush threshold; also the top bucket of the
    /// forward batch ladder (`1..=512`).
    pub max_batch: usize,
    /// Micro-batcher deadline flush: a partial batch waits at most this
    /// many simulated cycles (0 = flush immediately, batch-1 serving).
    pub max_wait_cycles: u64,
    /// Per-net admission-control backlog capacity: the maximum number
    /// of admitted-but-undispatched requests (queued **plus** formed
    /// batches waiting for a board) before submissions are refused with
    /// the typed [`ServeError::Overloaded`].
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            boards: 2,
            device: "XC7S75-2".into(),
            max_batch: 8,
            max_wait_cycles: 256,
            queue_cap: 1024,
        }
    }
}

/// One registered net: its artifact, pinned parameters, and queue.
struct NetEntry {
    artifact: Arc<Artifact>,
    w: Vec<Vec<i16>>,
    b: Vec<Vec<i16>>,
    in_dim: usize,
    out_dim: usize,
    batcher: MicroBatcher,
    /// Admitted requests not yet dispatched to a board (queued in the
    /// batcher **or** sitting in a formed batch awaiting a free board)
    /// — the quantity `queue_cap` bounds, so backlog cannot grow
    /// without bound even while every board is busy.
    outstanding: usize,
    metrics: NetMetrics,
}

/// One serving engine: a `(net, bucket)` forward plan plus this board's
/// private state, parameters pre-bound at creation.
struct Engine {
    variant: Arc<ForwardVariant>,
    plan: Arc<ExecPlan>,
    state: PlanState,
}

/// One board of the pool.
struct BoardState {
    /// Simulated cycle the board becomes free.
    busy_until: u64,
    /// False once the board was evicted ([`Server::evict_board`]): it
    /// takes no further batches; the shared ready queue redistributes
    /// onto the survivors.
    alive: bool,
    /// Lazily-created engines, keyed `(net, bucket)` (BTreeMap: the
    /// runtime never iterates hash-ordered state — determinism).
    engines: BTreeMap<(NetId, usize), Engine>,
}

/// A formed micro-batch waiting for a free board.
struct ReadyBatch {
    net: NetId,
    rows: Vec<Pending>,
}

/// One served request's result.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Request id (as returned by [`Server::submit_at`]).
    pub id: RequestId,
    /// Net the request targeted.
    pub net: NetId,
    /// Quantised output row (`out_dim` lanes) — bit-identical to what a
    /// batch-1 `Session::infer` produces with the same parameters.
    pub output: Vec<i16>,
    /// Simulated cycle the request was admitted.
    pub submitted: u64,
    /// Simulated cycle its micro-batch started on a board.
    pub dispatched: u64,
    /// Simulated cycle its micro-batch finished.
    pub completed: u64,
    /// Real rows in the micro-batch it rode in.
    pub batch_rows: usize,
    /// Ladder bucket the micro-batch ran at.
    pub bucket: usize,
}

/// The multi-tenant batched inference server over a simulated board
/// pool. See the module docs for the architecture; see
/// [`crate::session::Session::server`] for the one-net convenience
/// front door.
pub struct Server {
    cfg: ServeConfig,
    device: FpgaDevice,
    ladder: Vec<usize>,
    now: u64,
    next_id: RequestId,
    nets: Vec<NetEntry>,
    boards: Vec<BoardState>,
    board_metrics: Vec<BoardMetrics>,
    ready: VecDeque<ReadyBatch>,
    completions: Vec<Completion>,
}

impl Server {
    /// Open a serving runtime on `cfg` (validated; the forward batch
    /// ladder is `forward_buckets(cfg.max_batch)`).
    pub fn open(cfg: ServeConfig) -> Result<Server, ServeError> {
        let device = FpgaDevice::by_name(&cfg.device)
            .ok_or_else(|| ServeError::UnknownDevice(cfg.device.clone()))?;
        if cfg.boards == 0 {
            return Err(ServeError::Config("board pool must have at least 1 board".into()));
        }
        if cfg.max_batch == 0 || cfg.max_batch > COLUMN_LEN {
            return Err(ServeError::Config(format!(
                "max_batch {} out of range 1..={COLUMN_LEN}",
                cfg.max_batch
            )));
        }
        if cfg.queue_cap == 0 {
            return Err(ServeError::Config("queue_cap must be at least 1".into()));
        }
        let ladder = forward_buckets(cfg.max_batch);
        let boards = (0..cfg.boards)
            .map(|_| BoardState { busy_until: 0, alive: true, engines: BTreeMap::new() })
            .collect();
        let board_metrics = vec![BoardMetrics::default(); cfg.boards];
        Ok(Server {
            cfg,
            device,
            ladder,
            now: 0,
            next_id: 0,
            nets: Vec::new(),
            boards,
            board_metrics,
            ready: VecDeque::new(),
            completions: Vec::new(),
        })
    }

    /// Register a compiled net with explicit quantised parameters
    /// (per-layer weights/biases, e.g. from `Session::weights` after
    /// training). Returns the net's id. Engines compile lazily — the
    /// first micro-batch of each `(net, bucket)` pays the (cached)
    /// lowering+plan cost, every later one reuses it.
    pub fn register(
        &mut self,
        artifact: Arc<Artifact>,
        w: &[Vec<i16>],
        b: &[Vec<i16>],
    ) -> Result<NetId, ServeError> {
        let spec = artifact
            .spec()
            .ok_or_else(|| ServeError::NotServable {
                net: artifact.name().to_string(),
                why: "raw-program artifacts have no network structure".into(),
            })?
            .clone();
        if w.len() != spec.layers.len() || b.len() != spec.layers.len() {
            return Err(ServeError::NotServable {
                net: artifact.name().to_string(),
                why: format!(
                    "{} weight / {} bias layers for a {}-layer net",
                    w.len(),
                    b.len(),
                    spec.layers.len()
                ),
            });
        }
        for (l, layer) in spec.layers.iter().enumerate() {
            let want_w = layer.inputs * layer.outputs;
            if w[l].len() != want_w {
                return Err(ServeError::BadParams {
                    net: artifact.name().to_string(),
                    layer: l,
                    what: "weights",
                    want: want_w,
                    got: w[l].len(),
                });
            }
            if b[l].len() != layer.outputs {
                return Err(ServeError::BadParams {
                    net: artifact.name().to_string(),
                    layer: l,
                    what: "biases",
                    want: layer.outputs,
                    got: b[l].len(),
                });
            }
        }
        let id = self.nets.len();
        self.nets.push(NetEntry {
            metrics: NetMetrics { name: artifact.name().to_string(), ..NetMetrics::default() },
            artifact,
            w: w.to_vec(),
            b: b.to_vec(),
            in_dim: spec.input_dim(),
            out_dim: spec.output_dim(),
            batcher: MicroBatcher::new(
                self.cfg.max_batch,
                self.cfg.max_wait_cycles,
                self.cfg.queue_cap,
            ),
            outstanding: 0,
        });
        Ok(id)
    }

    /// The pool's simulated device.
    pub fn device(&self) -> FpgaDevice {
        self.device
    }

    /// Current simulated cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// The forward batch ladder buckets in use.
    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }

    /// Boards still accepting work.
    pub fn alive_boards(&self) -> usize {
        self.boards.iter().filter(|b| b.alive).count()
    }

    /// Evict a failed board from the pool (idempotent). The board takes
    /// no further batches — its in-flight micro-batch finishes at its
    /// already-scheduled completion cycle, and everything queued or
    /// formed redistributes onto the surviving boards through the
    /// shared ready queue (the serving twin of the cluster leader's
    /// board eviction: requests are **not** errored). Evicting the last
    /// board is allowed; the failure then surfaces as a typed
    /// [`ServeError::NoBoards`] on the next submit/drain that actually
    /// needs a board.
    pub fn evict_board(&mut self, board: usize) -> Result<(), ServeError> {
        if board >= self.boards.len() {
            return Err(ServeError::Config(format!(
                "evict_board({board}) out of range for a {}-board pool",
                self.boards.len()
            )));
        }
        if self.boards[board].alive {
            self.boards[board].alive = false;
            self.boards[board].engines.clear();
            self.board_metrics[board].evicted = true;
        }
        Ok(())
    }

    /// Submit one request (a quantised `input_dim` row for `net`) at
    /// simulated cycle `at` (must be ≥ the server's clock; the clock
    /// advances to `at`, firing any deadlines/dispatches due before it).
    /// Returns the request id, or the typed rejection.
    pub fn submit_at(
        &mut self,
        at: u64,
        net: NetId,
        row: &[i16],
    ) -> Result<RequestId, ServeError> {
        if at < self.now {
            return Err(ServeError::ClockSkew { at, now: self.now });
        }
        if net >= self.nets.len() {
            return Err(ServeError::UnknownNet(net));
        }
        if self.alive_boards() == 0 {
            return Err(ServeError::NoBoards { boards: self.boards.len() });
        }
        self.advance_to(at)?;
        let cap = self.cfg.queue_cap;
        let entry = &mut self.nets[net];
        if row.len() != entry.in_dim {
            return Err(ServeError::BadRow { net, want: entry.in_dim, got: row.len() });
        }
        // Admission bounds the whole undispatched backlog — queued
        // requests plus formed batches waiting for a board — not just
        // the batcher queue (which fill-flushes below max_batch and
        // would otherwise never refuse anything).
        if entry.outstanding >= cap {
            entry.metrics.rejected += 1;
            return Err(ServeError::Overloaded { net, depth: entry.outstanding, cap });
        }
        let id = self.next_id;
        if let Err(depth) =
            entry.batcher.push(Pending { id, row: row.to_vec(), arrival: at })
        {
            entry.metrics.rejected += 1;
            return Err(ServeError::Overloaded { net, depth, cap });
        }
        entry.outstanding += 1;
        entry.metrics.submitted += 1;
        entry.metrics.max_queue_depth = entry.metrics.max_queue_depth.max(entry.batcher.depth());
        self.next_id += 1;
        self.pump()?;
        Ok(id)
    }

    /// Run the simulation until every queue is empty and every formed
    /// batch has dispatched, then fast-forward the clock to the cycle
    /// the last board goes idle. Returns that cycle (the makespan).
    /// Terminates after finitely many events by construction — the
    /// serving half of the no-hang contract.
    pub fn drain(&mut self) -> Result<u64, ServeError> {
        while self.has_work() {
            let Some(e) = self.next_event() else {
                // Only possible when every board has been evicted while
                // work is still pending: typed, never a hang.
                return Err(ServeError::NoBoards { boards: self.boards.len() });
            };
            self.now = self.now.max(e);
            self.pump()?;
        }
        let idle = self.boards.iter().map(|b| b.busy_until).max().unwrap_or(self.now);
        self.now = self.now.max(idle);
        Ok(self.now)
    }

    /// Take the completions accumulated so far (dispatch order).
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Snapshot the serving metrics.
    pub fn report(&self) -> ServeReport {
        let makespan = self
            .boards
            .iter()
            .map(|b| b.busy_until)
            .max()
            .unwrap_or(0)
            .max(self.now);
        ServeReport {
            device: self.device,
            boards: self.board_metrics.clone(),
            nets: self.nets.iter().map(|n| n.metrics.clone()).collect(),
            makespan_cycles: makespan,
        }
    }

    // ------------------------------------------------------ event loop

    fn has_work(&self) -> bool {
        !self.ready.is_empty() || self.nets.iter().any(|n| n.batcher.depth() > 0)
    }

    /// Earliest future event: a queue's deadline flush, or — when formed
    /// batches are waiting — the earliest board-free time.
    fn next_event(&self) -> Option<u64> {
        let mut e: Option<u64> = None;
        let mut fold = |t: u64| e = Some(e.map_or(t, |x| x.min(t)));
        for n in &self.nets {
            if let Some(d) = n.batcher.deadline() {
                fold(d);
            }
        }
        if !self.ready.is_empty() {
            if let Some(b) =
                self.boards.iter().filter(|b| b.alive).map(|b| b.busy_until).min()
            {
                fold(b);
            }
        }
        e
    }

    /// Process everything due at the current cycle: flush due batches
    /// (stable net order), then dispatch FIFO batches onto the
    /// lowest-indexed free boards. After `pump` returns, no further
    /// progress is possible without advancing the clock.
    fn pump(&mut self) -> Result<(), ServeError> {
        for nid in 0..self.nets.len() {
            for rows in self.nets[nid].batcher.take_ready(self.now) {
                self.ready.push_back(ReadyBatch { net: nid, rows });
            }
        }
        while !self.ready.is_empty() {
            let Some(board) = self.free_board() else { break };
            let batch = self.ready.pop_front().expect("checked non-empty");
            self.dispatch(board, batch)?;
        }
        Ok(())
    }

    /// The lowest-indexed free **alive** board (`None` when all busy or
    /// evicted) — a deterministic placement rule.
    fn free_board(&self) -> Option<usize> {
        self.boards.iter().position(|b| b.alive && b.busy_until <= self.now)
    }

    /// Execute one micro-batch on `board` at the current cycle.
    fn dispatch(&mut self, board: usize, batch: ReadyBatch) -> Result<(), ServeError> {
        let nid = batch.net;
        let bucket = bucket_for(batch.rows.len(), &self.ladder)
            .expect("batch size is capped at max_batch, the ladder's top bucket");
        let entry = &self.nets[nid];
        // Lazily create the (net, bucket) engine on this board, binding
        // the net's pinned parameters once.
        if let std::collections::btree_map::Entry::Vacant(slot) =
            self.boards[board].engines.entry((nid, bucket))
        {
            let variant = entry
                .artifact
                .forward_variant(bucket)
                .map_err(|e| ServeError::Compile(e.to_string()))?;
            let plan = variant.plan_for(&self.device);
            let mut state = plan.state();
            let low = variant.lowered();
            for l in 0..entry.w.len() {
                plan.write_buffer(&mut state, low.weights[l], &entry.w[l]);
                plan.write_buffer(&mut state, low.biases[l], &entry.b[l]);
            }
            slot.insert(Engine { variant, plan, state });
        }
        // Assemble the padded row-major micro-batch (shared layout rule
        // with every evaluation chunk — see `dataset::flatten_rows`).
        let row_refs: Vec<&[i16]> = batch.rows.iter().map(|p| p.row.as_slice()).collect();
        let qx = dataset::flatten_rows(&row_refs, entry.in_dim, bucket);
        let out_dim = entry.out_dim;
        let engine = self.boards[board]
            .engines
            .get_mut(&(nid, bucket))
            .expect("engine created above");
        let low = engine.variant.lowered();
        let (x_id, out_id) = (low.x, low.out);
        let (out, stats) = engine.plan.run_forward(&mut engine.state, x_id, &qx, out_id);
        // Timing: the batch starts now (the board was free) and occupies
        // the board for the run's simulated cycles.
        let start = self.now;
        let done = start + stats.cycles;
        self.boards[board].busy_until = done;
        self.board_metrics[board].batches += 1;
        self.board_metrics[board].busy_cycles += stats.cycles;
        self.nets[nid].outstanding -= batch.rows.len();
        let m = &mut self.nets[nid].metrics;
        m.batches += 1;
        m.batch_rows += batch.rows.len() as u64;
        m.bucket_rows += bucket as u64;
        m.completed += batch.rows.len() as u64;
        for (i, p) in batch.rows.iter().enumerate() {
            m.latencies.push(done - p.arrival);
            self.completions.push(Completion {
                id: p.id,
                net: nid,
                output: out[i * out_dim..(i + 1) * out_dim].to_vec(),
                submitted: p.arrival,
                dispatched: start,
                completed: done,
                batch_rows: batch.rows.len(),
                bucket,
            });
        }
        Ok(())
    }

    /// Advance the simulated clock to `t`, firing every event on the
    /// way. Progress is strict: each pump resolves everything due at the
    /// current cycle, so the next event is always strictly later.
    fn advance_to(&mut self, t: u64) -> Result<(), ServeError> {
        loop {
            self.pump()?;
            match self.next_event() {
                Some(e) if e <= t => self.now = self.now.max(e),
                _ => break,
            }
        }
        self.now = self.now.max(t);
        self.pump()
    }
}
