//! The multi-tenant serving [`Server`]: per-net request queues, the
//! dynamic micro-batcher, bucket selection from the forward batch
//! ladder, and board-pool placement — driven as a **discrete-event
//! simulation** over the machine cycle model, so every run is
//! deterministic (same seed ⇒ same outputs, same metrics, bit for bit).
//!
//! ```text
//!   submit_with(cycle, net, row, {priority, deadline})
//!        │  admission control (shed-by-priority beyond queue_cap)
//!        ▼
//!   per-net FIFO queue ──▶ micro-batcher (flush on max_batch │ max_wait
//!        │                        │        │ deadline-slack urgency)
//!        │                        │ bucket = smallest ladder plan ≥ rows
//!        ▼                        ▼
//!   ready batches ──▶ board pool (healthiest-free board; FIFO batches)
//!                          │ ExecPlan::run_forward on the (net, bucket)
//!                          │ engine; service time = RunStats.cycles
//!                          │ fault plan: stall / corrupt / kill sites
//!                          ▼
//!                completions (outputs + latency) │ hedged retries
//!                dropped records (shed/expired)  │ quarantine, metrics
//! ```
//!
//! **Degraded mode** (see DESIGN.md §Serving): every request carries a
//! priority and an optional deadline; overload sheds the *worst*
//! undispatched request (lowest priority, then latest deadline) instead
//! of blanket-refusing arrivals; a [`super::fault::ServeFaultPlan`]
//! injects deterministic board faults; boards move Healthy →
//! Quarantined → probation on strikes; corrupt/stalled batches are
//! hedged onto the healthiest free board within a bounded retry budget;
//! and deadline-at-risk requests flush early onto a smaller, faster
//! ladder bucket.
//!
//! **No-hang contract** (the serving twin of the cluster's
//! "leader-never-hangs"): admission is bounded, every formed batch
//! dispatches at a finite board-free or quarantine-expiry time, and
//! [`Server::drain`] terminates after finitely many events — under any
//! survivable fault plan every admitted request terminates as a
//! [`Completion`] or a typed [`DroppedRequest`], never as a hang or a
//! silent drop. With an empty fault plan and default submit options the
//! runtime is bit-identical to the pre-degraded-mode server.

use super::batcher::{bucket_for, MicroBatcher, Pending};
use super::fault::{output_checksum, ServeFaultPlan};
use super::metrics::{BoardMetrics, NetMetrics, ServeReport};
use crate::hw::{ExecPlan, FpgaDevice, PlanState, COLUMN_LEN};
use crate::nn::dataset;
use crate::nn::lowering::forward_buckets;
use crate::session::artifact::ForwardVariant;
use crate::session::Artifact;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use thiserror::Error;

/// Index of a registered net (registration order).
pub type NetId = usize;

/// Server-assigned request id (monotonic across all nets).
pub type RequestId = u64;

/// Serving runtime errors — all typed; in particular overload is a
/// first-class shed decision, not a hang or a silent drop.
#[derive(Debug, Error)]
pub enum ServeError {
    /// Unknown FPGA part name.
    #[error("unknown FPGA part {0:?}")]
    UnknownDevice(String),
    /// Invalid server configuration.
    #[error("bad serve config: {0}")]
    Config(String),
    /// Net id was never registered.
    #[error("unknown net id {0}")]
    UnknownNet(NetId),
    /// Artifact cannot serve (raw program, missing network structure).
    #[error("artifact {net:?} is not servable: {why}")]
    NotServable {
        /// Artifact name.
        net: String,
        /// Why it cannot serve.
        why: String,
    },
    /// Registered parameters disagree with the net's layer shapes.
    #[error("net {net:?}: layer {layer} {what} expect {want} lanes, got {got}")]
    BadParams {
        /// Artifact name.
        net: String,
        /// Layer index.
        layer: usize,
        /// `"weights"` or `"biases"`.
        what: &'static str,
        /// Expected lane count.
        want: usize,
        /// Provided lane count.
        got: usize,
    },
    /// Request row has the wrong lane count for the target net.
    #[error("net {net}: request row has {got} lanes, expected {want}")]
    BadRow {
        /// Target net id.
        net: NetId,
        /// Expected lane count (`input_dim`).
        want: usize,
        /// Provided lane count.
        got: usize,
    },
    /// Admission control shed this request: the net's backlog —
    /// requests admitted but not yet dispatched to a board, whether
    /// still queued or already formed into waiting batches — is at
    /// capacity, and this request is the *worst* of the backlog plus
    /// itself (lowest priority, then latest deadline, then newest).
    /// Backlogged requests of strictly lower priority are shed first as
    /// [`DroppedRequest`] records instead — never this error.
    #[error(
        "net {net} shed priority-{priority} request: backlog {depth} at capacity {cap}"
    )]
    Shed {
        /// Target net id.
        net: NetId,
        /// Backlog (undispatched admitted requests) at shed time.
        depth: usize,
        /// Configured capacity.
        cap: usize,
        /// Priority of the shed (incoming) request.
        priority: u8,
    },
    /// The request's deadline already lies in the past at submit time —
    /// it could never complete in time, so it is refused immediately
    /// rather than admitted and expired later.
    #[error("net {net}: deadline cycle {deadline} is before submit cycle {at}")]
    DeadlineExceeded {
        /// Target net id.
        net: NetId,
        /// The requested absolute deadline cycle.
        deadline: u64,
        /// The submit cycle.
        at: u64,
    },
    /// Every board of the pool is dead (evicted or killed by the fault
    /// plan): nothing can serve the backlog (or admit new requests).
    /// Unlike a transient [`ServeError::Shed`] this is terminal for the
    /// server.
    #[error("all {boards} board(s) dead; cannot serve")]
    NoBoards {
        /// Pool size (all dead).
        boards: usize,
    },
    /// Submissions must carry a non-decreasing simulated clock.
    #[error("simulated clock must be monotonic: submit at cycle {at} before now {now}")]
    ClockSkew {
        /// Requested submission cycle.
        at: u64,
        /// Server's current simulated cycle.
        now: u64,
    },
    /// Lowering a forward-ladder bucket failed (unreachable for
    /// configurations that pass [`Server::open`] validation).
    #[error("forward ladder compile failed: {0}")]
    Compile(String),
}

/// Serving runtime configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Boards in the pool.
    pub boards: usize,
    /// Board part name (Table 8 catalog).
    pub device: String,
    /// Micro-batcher fill-flush threshold; also the top bucket of the
    /// forward batch ladder (`1..=512`).
    pub max_batch: usize,
    /// Micro-batcher wait-bound flush: a partial batch waits at most
    /// this many simulated cycles (0 = flush immediately, batch-1
    /// serving).
    pub max_wait_cycles: u64,
    /// Per-net admission-control backlog capacity: the maximum number
    /// of admitted-but-undispatched requests (queued **plus** formed
    /// batches waiting for a board) before a submission forces a shed
    /// decision — the worst backlogged request drops as a
    /// [`DroppedRequest`], or the incoming one is refused with the
    /// typed [`ServeError::Shed`].
    pub queue_cap: usize,
    /// Deterministic fault schedule (empty = fault-free serving,
    /// bit-identical to a server without degraded mode).
    pub faults: ServeFaultPlan,
    /// Hedged-retry budget: a micro-batch whose dispatch was corrupted
    /// or stall-detected is re-dispatched onto the healthiest free
    /// board at most this many times before its requests drop as
    /// [`DropReason::RetryBudget`].
    pub max_retries: usize,
    /// Strikes (detected faults) before a board is quarantined.
    pub quarantine_after: u32,
    /// Simulated cycles a quarantined board sits out before it may be
    /// re-admitted on probation.
    pub quarantine_cycles: u64,
    /// Watchdog: a dispatch holding a board longer than this many
    /// simulated cycles is declared stalled; the batch is hedged and
    /// the board struck (its late result is discarded).
    pub stall_timeout_cycles: u64,
    /// SLO urgency margin handed to every net's micro-batcher: a queued
    /// request within this many cycles of its deadline forces an early
    /// partial flush onto a smaller, faster ladder bucket.
    pub deadline_slack_cycles: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            boards: 2,
            device: "XC7S75-2".into(),
            max_batch: 8,
            max_wait_cycles: 256,
            queue_cap: 1024,
            faults: ServeFaultPlan::default(),
            max_retries: 3,
            quarantine_after: 2,
            quarantine_cycles: 4096,
            stall_timeout_cycles: 2048,
            deadline_slack_cycles: 64,
        }
    }
}

/// Per-request submit options: scheduling priority and optional SLO
/// deadline. [`Default`] (priority 0, no deadline) reproduces the
/// pre-degraded-mode behaviour exactly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SubmitOptions {
    /// Scheduling priority (higher = more important; sheds last).
    pub priority: u8,
    /// Absolute simulated-cycle deadline (`None` = best-effort).
    pub deadline: Option<u64>,
}

/// Why an *admitted* request was dropped (post-admission terminations;
/// submit-time refusals surface as [`ServeError`] instead). Every drop
/// is recorded — requests are never silently discarded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// Shed by admission control to make room for a better request
    /// (this one had the lowest priority / latest deadline).
    Shed,
    /// Its deadline passed while it waited for a board.
    DeadlineExceeded,
    /// Its micro-batch exhausted the hedged-retry budget
    /// (`max_retries`) against transient board faults.
    RetryBudget,
}

/// A typed record of one admitted request that was dropped instead of
/// completed. Take them with [`Server::take_dropped`]; the invariant
/// under any survivable fault plan is
/// `admitted == completions + dropped`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DroppedRequest {
    /// Request id (as returned by submit).
    pub id: RequestId,
    /// Net the request targeted.
    pub net: NetId,
    /// Why it dropped.
    pub reason: DropReason,
    /// Simulated cycle the drop was decided.
    pub at: u64,
    /// The request's priority.
    pub priority: u8,
    /// The request's deadline, if any.
    pub deadline: Option<u64>,
}

/// One registered net: its artifact, pinned parameters, and queue.
struct NetEntry {
    artifact: Arc<Artifact>,
    w: Vec<Vec<i16>>,
    b: Vec<Vec<i16>>,
    in_dim: usize,
    out_dim: usize,
    batcher: MicroBatcher,
    /// Admitted requests not yet dispatched to a board (queued in the
    /// batcher **or** sitting in a first-attempt formed batch awaiting
    /// a free board) — the quantity `queue_cap` bounds, so backlog
    /// cannot grow without bound even while every board is busy.
    outstanding: usize,
    metrics: NetMetrics,
}

/// One serving engine: a `(net, bucket)` forward plan plus this board's
/// private state, parameters pre-bound at creation.
struct Engine {
    variant: Arc<ForwardVariant>,
    plan: Arc<ExecPlan>,
    state: PlanState,
}

/// Board lifecycle (DESIGN.md §Serving, "Degraded mode"): healthy
/// boards accumulate strikes on detected faults; at
/// `quarantine_after` strikes the board sits out `quarantine_cycles`,
/// then re-admits on probation (strikes preserved, so the next strike
/// re-quarantines; a clean dispatch resets them). Death is terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Health {
    /// Serving (possibly on probation when `strikes > 0`).
    Up { strikes: u32 },
    /// Sitting out until the given simulated cycle.
    Quarantined { strikes: u32, until: u64 },
    /// Evicted or killed by the fault plan; never returns.
    Dead,
}

/// One board of the pool.
struct BoardState {
    /// Simulated cycle the board becomes free.
    busy_until: u64,
    /// Lifecycle state (see [`Health`]).
    health: Health,
    /// Dispatches started on this board — the fault plan's per-board
    /// `at` index.
    dispatches: usize,
    /// Lazily-created engines, keyed `(net, bucket)` (BTreeMap: the
    /// runtime never iterates hash-ordered state — determinism).
    engines: BTreeMap<(NetId, usize), Engine>,
}

/// A formed micro-batch waiting for a free board. `attempts` counts
/// executions so far (0 = never dispatched; retries keep the original
/// rows).
struct ReadyBatch {
    net: NetId,
    rows: Vec<Pending>,
    attempts: usize,
}

/// What a faulted dispatch resolves to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Verdict {
    /// A benign stall: the result is valid, just delivered late.
    DelayedOk,
    /// The output integrity word mismatched — retry.
    Corrupt,
    /// The watchdog fired before the board returned — retry; the late
    /// result is discarded.
    Stalled,
}

/// A dispatched micro-batch whose outcome resolves at a future cycle
/// (only fault-plan-affected dispatches go in flight; clean dispatches
/// complete synchronously at dispatch time, exactly as before).
struct InFlight {
    net: NetId,
    rows: Vec<Pending>,
    attempts: usize,
    board: usize,
    start: u64,
    resolve_at: u64,
    verdict: Verdict,
    /// Output block (valid for [`Verdict::DelayedOk`] only).
    out: Vec<i16>,
    bucket: usize,
}

/// One served request's result.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Request id (as returned by [`Server::submit_at`]).
    pub id: RequestId,
    /// Net the request targeted.
    pub net: NetId,
    /// Quantised output row (`out_dim` lanes) — bit-identical to what a
    /// batch-1 `Session::infer` produces with the same parameters.
    pub output: Vec<i16>,
    /// Simulated cycle the request was admitted.
    pub submitted: u64,
    /// Simulated cycle its micro-batch started on a board.
    pub dispatched: u64,
    /// Simulated cycle its micro-batch finished.
    pub completed: u64,
    /// Real rows in the micro-batch it rode in.
    pub batch_rows: usize,
    /// Ladder bucket the micro-batch ran at.
    pub bucket: usize,
}

/// Where the shed-victim scan found the worst request.
enum VictimLoc {
    /// The incoming request itself is the worst — refuse it.
    Incoming,
    /// A request still queued in the net's batcher.
    Queued(RequestId),
    /// A row of a formed first-attempt batch (`ready[i].rows[j]`).
    Ready(usize, usize),
}

/// Is candidate `a` strictly worse (shed sooner) than `b`? Keys are
/// `(priority, effective_deadline, id)`: lower priority is worse; ties
/// shed the latest deadline (`None` = latest possible), then the
/// newest request — so a uniform-priority, no-deadline workload always
/// sheds the incoming request, exactly the old `Overloaded` behaviour.
fn worse_than(a: (u8, u64, u64), b: (u8, u64, u64)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && (a.1 > b.1 || (a.1 == b.1 && a.2 > b.2)))
}

/// The multi-tenant batched inference server over a simulated board
/// pool. See the module docs for the architecture; see
/// [`crate::session::Session::server`] for the one-net convenience
/// front door.
pub struct Server {
    cfg: ServeConfig,
    device: FpgaDevice,
    ladder: Vec<usize>,
    now: u64,
    next_id: RequestId,
    nets: Vec<NetEntry>,
    boards: Vec<BoardState>,
    board_metrics: Vec<BoardMetrics>,
    ready: VecDeque<ReadyBatch>,
    inflight: Vec<InFlight>,
    completions: Vec<Completion>,
    dropped: Vec<DroppedRequest>,
}

impl Server {
    /// Open a serving runtime on `cfg` (validated; the forward batch
    /// ladder is `forward_buckets(cfg.max_batch)`).
    pub fn open(cfg: ServeConfig) -> Result<Server, ServeError> {
        let device = FpgaDevice::by_name(&cfg.device)
            .ok_or_else(|| ServeError::UnknownDevice(cfg.device.clone()))?;
        if cfg.boards == 0 {
            return Err(ServeError::Config("board pool must have at least 1 board".into()));
        }
        if cfg.max_batch == 0 || cfg.max_batch > COLUMN_LEN {
            return Err(ServeError::Config(format!(
                "max_batch {} out of range 1..={COLUMN_LEN}",
                cfg.max_batch
            )));
        }
        if cfg.queue_cap == 0 {
            return Err(ServeError::Config("queue_cap must be at least 1".into()));
        }
        if cfg.quarantine_after == 0 {
            return Err(ServeError::Config("quarantine_after must be at least 1 strike".into()));
        }
        if cfg.stall_timeout_cycles == 0 {
            return Err(ServeError::Config("stall_timeout_cycles must be positive".into()));
        }
        let ladder = forward_buckets(cfg.max_batch)
            .map_err(|e| ServeError::Config(e.to_string()))?;
        let boards = (0..cfg.boards)
            .map(|_| BoardState {
                busy_until: 0,
                health: Health::Up { strikes: 0 },
                dispatches: 0,
                engines: BTreeMap::new(),
            })
            .collect();
        let board_metrics = vec![BoardMetrics::default(); cfg.boards];
        Ok(Server {
            cfg,
            device,
            ladder,
            now: 0,
            next_id: 0,
            nets: Vec::new(),
            boards,
            board_metrics,
            ready: VecDeque::new(),
            inflight: Vec::new(),
            completions: Vec::new(),
            dropped: Vec::new(),
        })
    }

    /// Register a compiled net with explicit quantised parameters
    /// (per-layer weights/biases, e.g. from `Session::weights` after
    /// training). Returns the net's id. Engines compile lazily — the
    /// first micro-batch of each `(net, bucket)` pays the (cached)
    /// lowering+plan cost, every later one reuses it.
    pub fn register(
        &mut self,
        artifact: Arc<Artifact>,
        w: &[Vec<i16>],
        b: &[Vec<i16>],
    ) -> Result<NetId, ServeError> {
        // Shapes come from the net's first-class identity
        // (`NetSpec::param_shapes`), so MLP and operator-graph artifacts
        // validate and serve through the same path.
        let (shapes, in_dim, out_dim) = {
            let spec = artifact.net_spec().ok_or_else(|| ServeError::NotServable {
                net: artifact.name().to_string(),
                why: "raw-program artifacts have no network structure".into(),
            })?;
            (spec.param_shapes(), spec.input_dim(), spec.output_dim())
        };
        if w.len() != shapes.len() || b.len() != shapes.len() {
            return Err(ServeError::NotServable {
                net: artifact.name().to_string(),
                why: format!(
                    "{} weight / {} bias tensors for a net with {} parameter pairs",
                    w.len(),
                    b.len(),
                    shapes.len()
                ),
            });
        }
        for (l, &(rows, cols)) in shapes.iter().enumerate() {
            if w[l].len() != rows * cols {
                return Err(ServeError::BadParams {
                    net: artifact.name().to_string(),
                    layer: l,
                    what: "weights",
                    want: rows * cols,
                    got: w[l].len(),
                });
            }
            if b[l].len() != cols {
                return Err(ServeError::BadParams {
                    net: artifact.name().to_string(),
                    layer: l,
                    what: "biases",
                    want: cols,
                    got: b[l].len(),
                });
            }
        }
        let id = self.nets.len();
        self.nets.push(NetEntry {
            metrics: NetMetrics { name: artifact.name().to_string(), ..NetMetrics::default() },
            artifact,
            w: w.to_vec(),
            b: b.to_vec(),
            in_dim,
            out_dim,
            batcher: MicroBatcher::new(
                self.cfg.max_batch,
                self.cfg.max_wait_cycles,
                self.cfg.queue_cap,
                self.cfg.deadline_slack_cycles,
            ),
            outstanding: 0,
        });
        Ok(id)
    }

    /// The pool's simulated device.
    pub fn device(&self) -> FpgaDevice {
        self.device
    }

    /// Current simulated cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// The forward batch ladder buckets in use.
    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }

    /// Boards still accepting work (healthy or quarantined — not dead).
    pub fn alive_boards(&self) -> usize {
        self.boards.iter().filter(|b| b.health != Health::Dead).count()
    }

    /// Evict a failed board from the pool (**idempotent** — evicting an
    /// already-dead board changes nothing, so external health checks
    /// may fire redundantly without miscounting `alive_boards`). The
    /// board takes no further batches — its in-flight micro-batch
    /// finishes at its already-scheduled completion cycle, and
    /// everything queued or formed redistributes onto the surviving
    /// boards through the shared ready queue (the serving twin of the
    /// cluster leader's board eviction: requests are **not** errored).
    /// Evicting the last board is allowed; the failure then surfaces as
    /// a typed [`ServeError::NoBoards`] on the next submit/drain that
    /// actually needs a board.
    pub fn evict_board(&mut self, board: usize) -> Result<(), ServeError> {
        if board >= self.boards.len() {
            return Err(ServeError::Config(format!(
                "evict_board({board}) out of range for a {}-board pool",
                self.boards.len()
            )));
        }
        self.mark_dead(board);
        Ok(())
    }

    /// Submit one request (a quantised `input_dim` row for `net`) at
    /// simulated cycle `at` with default options (priority 0, no
    /// deadline — the pre-degraded-mode behaviour). See
    /// [`Server::submit_with`].
    pub fn submit_at(
        &mut self,
        at: u64,
        net: NetId,
        row: &[i16],
    ) -> Result<RequestId, ServeError> {
        self.submit_with(at, net, row, SubmitOptions::default())
    }

    /// Submit one request with explicit [`SubmitOptions`] at simulated
    /// cycle `at` (must be ≥ the server's clock; the clock advances to
    /// `at`, firing any deadlines/dispatches due before it). Returns
    /// the request id, or the typed rejection. When the net's backlog
    /// is at capacity the *worst* request of backlog ∪ {incoming} is
    /// shed: a backlogged victim drops as a [`DroppedRequest`] and the
    /// incoming request is admitted; the incoming request itself is
    /// refused with [`ServeError::Shed`] only when nothing in the
    /// backlog is worse.
    pub fn submit_with(
        &mut self,
        at: u64,
        net: NetId,
        row: &[i16],
        opts: SubmitOptions,
    ) -> Result<RequestId, ServeError> {
        if at < self.now {
            return Err(ServeError::ClockSkew { at, now: self.now });
        }
        if net >= self.nets.len() {
            return Err(ServeError::UnknownNet(net));
        }
        if self.alive_boards() == 0 {
            return Err(ServeError::NoBoards { boards: self.boards.len() });
        }
        self.advance_to(at)?;
        let cap = self.cfg.queue_cap;
        if row.len() != self.nets[net].in_dim {
            return Err(ServeError::BadRow {
                net,
                want: self.nets[net].in_dim,
                got: row.len(),
            });
        }
        if let Some(d) = opts.deadline {
            if d < at {
                self.nets[net].metrics.rejected += 1;
                return Err(ServeError::DeadlineExceeded { net, deadline: d, at });
            }
        }
        let id = self.next_id;
        // Admission bounds the whole undispatched backlog — queued
        // requests plus first-attempt formed batches waiting for a
        // board — not just the batcher queue (which fill-flushes below
        // max_batch and would otherwise never refuse anything). At
        // capacity, shed the worst of backlog ∪ {incoming}.
        if self.nets[net].outstanding >= cap {
            let depth = self.nets[net].outstanding;
            match self.find_victim(net, opts, id) {
                VictimLoc::Incoming => {
                    self.nets[net].metrics.rejected += 1;
                    return Err(ServeError::Shed { net, depth, cap, priority: opts.priority });
                }
                VictimLoc::Queued(vid) => {
                    let p = self.nets[net]
                        .batcher
                        .remove(vid)
                        .expect("victim scanned from the queue");
                    self.drop_request(net, &p, DropReason::Shed);
                    self.nets[net].outstanding -= 1;
                }
                VictimLoc::Ready(bi, ri) => {
                    let p = self.ready[bi].rows.remove(ri);
                    if self.ready[bi].rows.is_empty() {
                        self.ready.remove(bi);
                    }
                    self.drop_request(net, &p, DropReason::Shed);
                    self.nets[net].outstanding -= 1;
                }
            }
        }
        let entry = &mut self.nets[net];
        if let Err(depth) = entry.batcher.push(Pending {
            id,
            row: row.to_vec(),
            arrival: at,
            priority: opts.priority,
            deadline: opts.deadline,
        }) {
            entry.metrics.rejected += 1;
            return Err(ServeError::Shed { net, depth, cap, priority: opts.priority });
        }
        entry.outstanding += 1;
        entry.metrics.submitted += 1;
        entry.metrics.max_queue_depth = entry.metrics.max_queue_depth.max(entry.batcher.depth());
        self.next_id += 1;
        self.pump()?;
        Ok(id)
    }

    /// Run the simulation until every queue is empty, every formed
    /// batch has dispatched, and every in-flight outcome has resolved,
    /// then fast-forward the clock to the cycle the last board goes
    /// idle. Returns that cycle (the makespan). Terminates after
    /// finitely many events by construction — the serving half of the
    /// no-hang contract.
    pub fn drain(&mut self) -> Result<u64, ServeError> {
        while self.has_work() {
            let Some(e) = self.next_event() else {
                // Only possible when every board is dead while work is
                // still pending: typed, never a hang.
                return Err(ServeError::NoBoards { boards: self.boards.len() });
            };
            self.now = self.now.max(e);
            self.pump()?;
        }
        let idle = self.boards.iter().map(|b| b.busy_until).max().unwrap_or(self.now);
        self.now = self.now.max(idle);
        Ok(self.now)
    }

    /// Take the completions accumulated so far (dispatch order; delayed
    /// results in resolution order).
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Take the typed drop records accumulated so far (decision order).
    /// Under any survivable fault plan,
    /// `admitted == completions + dropped` — no silent losses.
    pub fn take_dropped(&mut self) -> Vec<DroppedRequest> {
        std::mem::take(&mut self.dropped)
    }

    /// Snapshot the serving metrics.
    pub fn report(&self) -> ServeReport {
        let makespan = self
            .boards
            .iter()
            .map(|b| b.busy_until)
            .max()
            .unwrap_or(0)
            .max(self.now);
        ServeReport {
            device: self.device,
            boards: self.board_metrics.clone(),
            nets: self.nets.iter().map(|n| n.metrics.clone()).collect(),
            makespan_cycles: makespan,
        }
    }

    // ------------------------------------------------------ degraded mode

    /// Record one post-admission drop (typed — never silent).
    fn drop_request(&mut self, net: NetId, p: &Pending, reason: DropReason) {
        match reason {
            DropReason::Shed | DropReason::RetryBudget => self.nets[net].metrics.shed += 1,
            DropReason::DeadlineExceeded => self.nets[net].metrics.expired += 1,
        }
        self.dropped.push(DroppedRequest {
            id: p.id,
            net,
            reason,
            at: self.now,
            priority: p.priority,
            deadline: p.deadline,
        });
    }

    /// Scan the net's undispatched backlog plus the incoming request
    /// for the worst candidate (see [`worse_than`]). Only first-attempt
    /// ready batches participate — retried batches already left the
    /// admission-controlled backlog.
    fn find_victim(&self, net: NetId, opts: SubmitOptions, incoming_id: RequestId) -> VictimLoc {
        let mut worst_key =
            (opts.priority, opts.deadline.unwrap_or(u64::MAX), incoming_id);
        let mut worst = VictimLoc::Incoming;
        for p in self.nets[net].batcher.iter() {
            let key = (p.priority, p.effective_deadline(), p.id);
            if worse_than(key, worst_key) {
                worst_key = key;
                worst = VictimLoc::Queued(p.id);
            }
        }
        for (bi, batch) in self.ready.iter().enumerate() {
            if batch.net != net || batch.attempts != 0 {
                continue;
            }
            for (ri, p) in batch.rows.iter().enumerate() {
                let key = (p.priority, p.effective_deadline(), p.id);
                if worse_than(key, worst_key) {
                    worst_key = key;
                    worst = VictimLoc::Ready(bi, ri);
                }
            }
        }
        worst
    }

    /// Terminal board death (idempotent): eviction and fault-plan kills
    /// share this path.
    fn mark_dead(&mut self, board: usize) {
        if self.boards[board].health != Health::Dead {
            self.boards[board].health = Health::Dead;
            self.boards[board].engines.clear();
            self.board_metrics[board].evicted = true;
        }
    }

    /// One detected fault on `board`: count a strike and quarantine at
    /// the configured threshold.
    fn strike(&mut self, board: usize) {
        let q = self.cfg.quarantine_cycles;
        let threshold = self.cfg.quarantine_after;
        self.board_metrics[board].strikes += 1;
        match self.boards[board].health {
            Health::Up { strikes } => {
                let s = strikes + 1;
                if s >= threshold {
                    self.boards[board].health =
                        Health::Quarantined { strikes: s, until: self.now + q };
                    self.board_metrics[board].quarantines += 1;
                } else {
                    self.boards[board].health = Health::Up { strikes: s };
                }
            }
            Health::Quarantined { strikes, until } => {
                self.boards[board].health = Health::Quarantined {
                    strikes: strikes + 1,
                    until: until.max(self.now + q),
                };
            }
            Health::Dead => {}
        }
    }

    // ------------------------------------------------------ event loop

    fn has_work(&self) -> bool {
        !self.ready.is_empty()
            || !self.inflight.is_empty()
            || self.nets.iter().any(|n| n.batcher.depth() > 0)
    }

    /// Earliest future event: a queue's flush trigger, an in-flight
    /// outcome resolving, or — when formed batches are waiting — the
    /// earliest cycle any non-dead board can take work (its free time,
    /// pushed past its quarantine expiry if it is sitting out).
    fn next_event(&self) -> Option<u64> {
        let mut e: Option<u64> = None;
        let mut fold = |t: u64| e = Some(e.map_or(t, |x| x.min(t)));
        for n in &self.nets {
            if let Some(d) = n.batcher.deadline() {
                fold(d);
            }
        }
        for f in &self.inflight {
            fold(f.resolve_at);
        }
        if !self.ready.is_empty() {
            if let Some(b) = self
                .boards
                .iter()
                .filter_map(|b| match b.health {
                    Health::Up { .. } => Some(b.busy_until),
                    Health::Quarantined { until, .. } => Some(until.max(b.busy_until)),
                    Health::Dead => None,
                })
                .min()
            {
                fold(b);
            }
        }
        e
    }

    /// Process everything due at the current cycle: resolve in-flight
    /// outcomes (delayed completions, strikes, hedged retries), flush
    /// due batches (stable net order), then dispatch FIFO batches onto
    /// the healthiest free boards. After `pump` returns, no further
    /// progress is possible without advancing the clock.
    fn pump(&mut self) -> Result<(), ServeError> {
        self.resolve_inflight();
        for nid in 0..self.nets.len() {
            for rows in self.nets[nid].batcher.take_ready(self.now) {
                self.ready.push_back(ReadyBatch { net: nid, rows, attempts: 0 });
            }
        }
        while !self.ready.is_empty() {
            let Some(board) = self.pick_board() else { break };
            let batch = self.ready.pop_front().expect("checked non-empty");
            self.dispatch(board, batch)?;
        }
        Ok(())
    }

    /// Resolve every in-flight outcome due at the current cycle, in
    /// dispatch order: benign delays deliver their results; detected
    /// corruptions/stalls strike the board and hedge the batch onto the
    /// ready queue's front (next free board), or drop its requests once
    /// the retry budget is exhausted.
    fn resolve_inflight(&mut self) {
        let due: Vec<InFlight> = {
            let mut rest = Vec::with_capacity(self.inflight.len());
            let mut due = Vec::new();
            for f in self.inflight.drain(..) {
                if f.resolve_at <= self.now {
                    due.push(f);
                } else {
                    rest.push(f);
                }
            }
            self.inflight = rest;
            due
        };
        for f in due {
            match f.verdict {
                Verdict::DelayedOk => self.deliver(&f),
                Verdict::Corrupt | Verdict::Stalled => {
                    self.strike(f.board);
                    // `attempts` counts executions so far; re-dispatch
                    // number `attempts` must stay within the budget.
                    if f.attempts > self.cfg.max_retries {
                        for p in &f.rows {
                            self.drop_request(f.net, p, DropReason::RetryBudget);
                        }
                    } else {
                        self.nets[f.net].metrics.retries += 1;
                        self.ready.push_front(ReadyBatch {
                            net: f.net,
                            rows: f.rows,
                            attempts: f.attempts,
                        });
                    }
                }
            }
        }
    }

    /// Deliver a delayed (benign-stall) batch: completions carry the
    /// stalled finish cycle, so SLO accounting sees the real latency.
    fn deliver(&mut self, f: &InFlight) {
        let out_dim = self.nets[f.net].out_dim;
        let m = &mut self.nets[f.net].metrics;
        m.completed += f.rows.len() as u64;
        for (i, p) in f.rows.iter().enumerate() {
            m.latencies.push(f.resolve_at - p.arrival);
            if p.deadline.is_some_and(|d| d < f.resolve_at) {
                m.late += 1;
            }
            self.completions.push(Completion {
                id: p.id,
                net: f.net,
                output: f.out[i * out_dim..(i + 1) * out_dim].to_vec(),
                submitted: p.arrival,
                dispatched: f.start,
                completed: f.resolve_at,
                batch_rows: f.rows.len(),
                bucket: f.bucket,
            });
        }
    }

    /// The healthiest free non-dead board: lowest strike count, then
    /// lowest index, among boards that are free now (`busy_until ≤
    /// now`) and not sitting out a quarantine. Selecting a board whose
    /// quarantine has expired re-admits it on probation (strikes
    /// preserved). With zero strikes everywhere this is exactly the old
    /// lowest-indexed-free rule.
    fn pick_board(&mut self) -> Option<usize> {
        let mut best: Option<(u32, usize)> = None;
        for (i, b) in self.boards.iter().enumerate() {
            if b.busy_until > self.now {
                continue;
            }
            let strikes = match b.health {
                Health::Up { strikes } => strikes,
                Health::Quarantined { strikes, until } if until <= self.now => strikes,
                _ => continue,
            };
            if best.map_or(true, |k| (strikes, i) < k) {
                best = Some((strikes, i));
            }
        }
        let (_, i) = best?;
        if let Health::Quarantined { strikes, .. } = self.boards[i].health {
            self.boards[i].health = Health::Up { strikes };
        }
        Some(i)
    }

    /// Execute one micro-batch on `board` at the current cycle,
    /// applying any fault-plan site scheduled for this board's next
    /// dispatch index.
    fn dispatch(&mut self, board: usize, mut batch: ReadyBatch) -> Result<(), ServeError> {
        let nid = batch.net;
        // Expire requests whose deadline already passed while they
        // waited (typed drops — never run work nobody can use).
        let mut i = 0;
        while i < batch.rows.len() {
            if batch.rows[i].deadline.is_some_and(|d| d < self.now) {
                let p = batch.rows.remove(i);
                self.drop_request(nid, &p, DropReason::DeadlineExceeded);
                if batch.attempts == 0 {
                    self.nets[nid].outstanding -= 1;
                }
            } else {
                i += 1;
            }
        }
        if batch.rows.is_empty() {
            return Ok(());
        }
        let k = self.boards[board].dispatches;
        self.boards[board].dispatches += 1;
        if self.cfg.faults.kills(board, k) {
            // The board dies taking the batch: nothing ran. Requeue at
            // the front — the batch redistributes to the survivors
            // without consuming retry budget.
            self.mark_dead(board);
            self.ready.push_front(batch);
            return Ok(());
        }
        if batch.attempts == 0 {
            self.nets[nid].outstanding -= batch.rows.len();
        }
        let bucket = bucket_for(batch.rows.len(), &self.ladder)
            .expect("batch size is capped at max_batch, the ladder's top bucket");
        let entry = &self.nets[nid];
        // Lazily create the (net, bucket) engine on this board, binding
        // the net's pinned parameters once.
        if let std::collections::btree_map::Entry::Vacant(slot) =
            self.boards[board].engines.entry((nid, bucket))
        {
            let variant = entry
                .artifact
                .forward_variant(bucket)
                .map_err(|e| ServeError::Compile(e.to_string()))?;
            let plan = variant.plan_for(&self.device);
            let mut state = plan.state();
            let low = variant.lowered();
            for l in 0..entry.w.len() {
                plan.write_buffer(&mut state, low.weights[l], &entry.w[l]);
                plan.write_buffer(&mut state, low.biases[l], &entry.b[l]);
            }
            slot.insert(Engine { variant, plan, state });
        }
        // Assemble the padded row-major micro-batch (shared layout rule
        // with every evaluation chunk — see `dataset::flatten_rows`).
        let row_refs: Vec<&[i16]> = batch.rows.iter().map(|p| p.row.as_slice()).collect();
        let qx = dataset::flatten_rows(&row_refs, entry.in_dim, bucket);
        let out_dim = entry.out_dim;
        let engine = self.boards[board]
            .engines
            .get_mut(&(nid, bucket))
            .expect("engine created above");
        let low = engine.variant.lowered();
        let (x_id, out_id) = (low.x, low.out);
        let (out, stats) = engine.plan.run_forward(&mut engine.state, x_id, &qx, out_id);
        // Timing: the batch starts now (the board was free) and occupies
        // the board for the run's simulated cycles (plus any injected
        // stall).
        let start = self.now;
        let done = start + stats.cycles;
        self.board_metrics[board].batches += 1;
        self.board_metrics[board].busy_cycles += stats.cycles;
        let m = &mut self.nets[nid].metrics;
        m.batches += 1;
        m.batch_rows += batch.rows.len() as u64;
        m.bucket_rows += bucket as u64;
        // Fault verdict for this dispatch. The board computes the
        // output integrity word before readback; a corruption site
        // flips the block afterwards, and the checksum mismatch — not
        // the plan — is what marks the batch corrupt, so the detection
        // path itself is exercised.
        if self.cfg.faults.corrupts(board, k) {
            let expected = output_checksum(&out);
            let mut bad = out;
            bad[0] ^= 1;
            let verdict = if output_checksum(&bad) == expected {
                Verdict::DelayedOk
            } else {
                Verdict::Corrupt
            };
            self.boards[board].busy_until = done;
            self.inflight.push(InFlight {
                net: nid,
                rows: batch.rows,
                attempts: batch.attempts + 1,
                board,
                start,
                resolve_at: done,
                verdict,
                out: bad,
                bucket,
            });
            return Ok(());
        }
        if let Some(stall) = self.cfg.faults.stall_cycles(board, k) {
            let actual = done + stall;
            self.boards[board].busy_until = actual;
            let detected = actual - start > self.cfg.stall_timeout_cycles;
            let (verdict, resolve_at) = if detected {
                // Watchdog fires first: hedge the batch; the board's
                // late (valid) result is discarded.
                (Verdict::Stalled, start + self.cfg.stall_timeout_cycles)
            } else {
                (Verdict::DelayedOk, actual)
            };
            self.inflight.push(InFlight {
                net: nid,
                rows: batch.rows,
                attempts: batch.attempts + 1,
                board,
                start,
                resolve_at,
                verdict,
                out,
                bucket,
            });
            return Ok(());
        }
        // Clean dispatch: the fault-free fast path, byte-for-byte the
        // pre-degraded-mode behaviour. A clean run clears the board's
        // probation strikes.
        self.boards[board].busy_until = done;
        self.boards[board].health = Health::Up { strikes: 0 };
        m.completed += batch.rows.len() as u64;
        for (i, p) in batch.rows.iter().enumerate() {
            m.latencies.push(done - p.arrival);
            if p.deadline.is_some_and(|d| d < done) {
                m.late += 1;
            }
            self.completions.push(Completion {
                id: p.id,
                net: nid,
                output: out[i * out_dim..(i + 1) * out_dim].to_vec(),
                submitted: p.arrival,
                dispatched: start,
                completed: done,
                batch_rows: batch.rows.len(),
                bucket,
            });
        }
        Ok(())
    }

    /// Advance the simulated clock to `t`, firing every event on the
    /// way. Progress is strict: each pump resolves everything due at the
    /// current cycle, so the next event is always strictly later.
    fn advance_to(&mut self, t: u64) -> Result<(), ServeError> {
        loop {
            self.pump()?;
            match self.next_event() {
                Some(e) if e <= t => self.now = self.now.max(e),
                _ => break,
            }
        }
        self.now = self.now.max(t);
        self.pump()
    }
}
