//! Deterministic fault injection for the serving runtime — the serving
//! twin of [`crate::cluster::fault::FaultPlan`].
//!
//! A [`ServeFaultPlan`] is a pure schedule: every fault is addressed by
//! an explicit `(board, dispatch-index)` site, with no randomness at
//! injection time, so the same plan replays bit-identically against the
//! same workload. Three transient/terminal fault kinds model what a
//! flaky FPGA does to an inference pool:
//!
//! * **stall** — the board holds its micro-batch for `cycles` extra
//!   simulated cycles past the plan's charged compute time. Short
//!   stalls are benign delays (the result is delivered late); stalls
//!   past the server's `stall_timeout_cycles` watchdog are detected and
//!   the batch is hedged onto another board.
//! * **corruption** — the batch's output block is flipped *after* the
//!   board computed its [`output_checksum`] integrity word (simulated
//!   readback corruption); the server detects the mismatch and retries
//!   the batch. The integrity word is the serving analogue of
//!   [`crate::cluster::bus::params_checksum`].
//! * **death** — the board drops out of the pool at the instant it
//!   would take its `at`-th micro-batch; the batch redistributes to the
//!   survivors and the board is permanently dead (same terminal state
//!   as [`crate::serve::Server::evict_board`]).
//!
//! The contract the server upholds under any *survivable* plan (deaths
//! leave ≥ 1 board, transient sites within the hedged-retry budget):
//! **never hang, never drop silently** — every admitted request
//! terminates as a completion or a typed
//! [`crate::serve::DroppedRequest`] record (DESIGN.md §Serving,
//! "Degraded mode").

use crate::util::Rng;

/// One injected fault site, addressed by board + that board's
/// dispatch index (the `at`-th micro-batch the board starts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeFaultSite {
    /// Target board.
    pub board: usize,
    /// Per-board dispatch index the fault fires at.
    pub at: usize,
}

/// A stall site: the dispatch holds the board for `cycles` extra
/// simulated cycles before the result becomes readable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StallSite {
    /// Target board.
    pub board: usize,
    /// Per-board dispatch index the stall fires at.
    pub at: usize,
    /// Extra simulated cycles the board holds the batch.
    pub cycles: u64,
}

/// A deterministic fault schedule for one serving run. Empty by default
/// (no faults — the server is then bit-identical to a fault-free
/// build); [`crate::serve::ServeConfig`] carries one per server.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServeFaultPlan {
    /// Stall the board's `at`-th dispatch for extra cycles.
    pub stalls: Vec<StallSite>,
    /// Corrupt the output block of the board's `at`-th dispatch after
    /// its integrity word was computed (detected via
    /// [`output_checksum`], then hedged onto another board).
    pub corruptions: Vec<ServeFaultSite>,
    /// Kill the board at its `at`-th dispatch (terminal, like
    /// [`crate::serve::Server::evict_board`]); the batch redistributes.
    pub deaths: Vec<ServeFaultSite>,
}

impl ServeFaultPlan {
    /// The empty plan (no faults) — what [`Default`] gives.
    pub fn none() -> ServeFaultPlan {
        ServeFaultPlan::default()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.stalls.is_empty() && self.corruptions.is_empty() && self.deaths.is_empty()
    }

    /// Transient (retryable) fault sites: stalls + corruptions. A plan
    /// is within a server's hedged-retry budget when this does not
    /// exceed `max_retries` — the worst case is one logical batch
    /// absorbing every transient site across its retries.
    pub fn transient_sites(&self) -> usize {
        self.stalls.len() + self.corruptions.len()
    }

    /// True when the plan is survivable by a `boards`-sized pool with
    /// `max_retries` hedged retries: deaths leave at least one board
    /// alive and the transient sites fit the retry budget. Under a
    /// survivable plan every admitted request must terminate as
    /// Completed, Shed, or DeadlineExceeded — never hang.
    pub fn is_survivable(&self, boards: usize, max_retries: usize) -> bool {
        let mut dead: Vec<usize> = self.deaths.iter().map(|s| s.board).collect();
        dead.sort_unstable();
        dead.dedup();
        dead.len() < boards && self.transient_sites() <= max_retries
    }

    /// Schedule a stall of `cycles` on `board`'s `at`-th dispatch.
    pub fn stall(mut self, board: usize, at: usize, cycles: u64) -> ServeFaultPlan {
        self.stalls.push(StallSite { board, at, cycles });
        self
    }

    /// Schedule an output corruption on `board`'s `at`-th dispatch.
    pub fn corrupt(mut self, board: usize, at: usize) -> ServeFaultPlan {
        self.corruptions.push(ServeFaultSite { board, at });
        self
    }

    /// Schedule a board death at `board`'s `at`-th dispatch.
    pub fn kill(mut self, board: usize, at: usize) -> ServeFaultPlan {
        self.deaths.push(ServeFaultSite { board, at });
        self
    }

    /// Generate a seeded **survivable** plan for a `boards`-sized pool
    /// with `max_retries` hedged retries — the shared chaos-plan source
    /// of `mfnn serve-sim --chaos` and the `serve-chaos` fuzz family.
    /// Board 0 is never killed (≥ 1 survivor) and at most `max_retries`
    /// transient sites are scheduled, each at a distinct
    /// `(board, dispatch)` site.
    pub fn survivable(seed: u64, boards: usize, max_retries: usize) -> ServeFaultPlan {
        let mut r = Rng::new(seed);
        let mut plan = ServeFaultPlan::none();
        // Deaths: any subset of boards 1.. (board 0 always survives).
        for b in 1..boards {
            if r.gen_bool(0.4) {
                plan = plan.kill(b, r.gen_range(6) as usize);
            }
        }
        // Transient sites within the retry budget, at distinct sites.
        let transients = if max_retries == 0 { 0 } else { r.gen_range(max_retries as u64 + 1) };
        let mut used: Vec<(usize, usize)> = Vec::new();
        for _ in 0..transients {
            let board = r.gen_range(boards as u64) as usize;
            let at = r.gen_range(8) as usize;
            let stall = r.gen_bool(0.5);
            let cycles = 1 + r.gen_range(4096);
            if used.contains(&(board, at)) {
                continue;
            }
            used.push((board, at));
            plan = if stall { plan.stall(board, at, cycles) } else { plan.corrupt(board, at) };
        }
        plan
    }

    fn hits(sites: &[ServeFaultSite], board: usize, at: usize) -> bool {
        sites.iter().any(|s| s.board == board && s.at == at)
    }

    /// Is the output of `board`'s `at`-th dispatch corrupted?
    pub(crate) fn corrupts(&self, board: usize, at: usize) -> bool {
        Self::hits(&self.corruptions, board, at)
    }

    /// Does `board` die at its `at`-th dispatch?
    pub(crate) fn kills(&self, board: usize, at: usize) -> bool {
        Self::hits(&self.deaths, board, at)
    }

    /// Extra cycles `board`'s `at`-th dispatch stalls for, if any.
    pub(crate) fn stall_cycles(&self, board: usize, at: usize) -> Option<u64> {
        self.stalls.iter().find(|s| s.board == board && s.at == at).map(|s| s.cycles)
    }
}

/// FNV-1a integrity word over an output block — the serving analogue of
/// [`crate::cluster::bus::params_checksum`]: the board computes it over
/// the micro-batch's output lanes before readback, so any later
/// corruption of the block is detected as a mismatch and the batch is
/// hedged instead of delivering wrong lanes.
pub fn output_checksum(out: &[i16]) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    let mut eat = |byte: u8| {
        h ^= byte as u64;
        h = h.wrapping_mul(PRIME);
    };
    for byte in (out.len() as u64).to_le_bytes() {
        eat(byte);
    }
    for v in out {
        for byte in v.to_le_bytes() {
            eat(byte);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_injects_nothing() {
        let p = ServeFaultPlan::none();
        assert!(p.is_empty());
        assert!(!p.corrupts(0, 0));
        assert!(!p.kills(0, 0));
        assert_eq!(p.stall_cycles(0, 0), None);
        assert!(p.is_survivable(1, 0));
    }

    #[test]
    fn sites_address_board_and_dispatch_exactly() {
        let p = ServeFaultPlan::none().kill(1, 2).corrupt(0, 0).stall(2, 1, 99);
        assert!(p.kills(1, 2));
        assert!(!p.kills(1, 1));
        assert!(!p.kills(2, 2));
        assert!(p.corrupts(0, 0));
        assert!(!p.corrupts(0, 1));
        assert_eq!(p.stall_cycles(2, 1), Some(99));
        assert_eq!(p.stall_cycles(2, 0), None);
        assert!(!p.is_empty());
    }

    #[test]
    fn survivability_checks_deaths_and_retry_budget() {
        let p = ServeFaultPlan::none().kill(1, 0).corrupt(0, 1);
        assert!(p.is_survivable(2, 1));
        assert!(!p.is_survivable(1, 1), "killing the whole pool is lethal");
        assert!(!p.is_survivable(2, 0), "one transient site needs one retry");
        // duplicate deaths of one board count once
        let q = ServeFaultPlan::none().kill(1, 0).kill(1, 3);
        assert!(q.is_survivable(2, 0));
    }

    #[test]
    fn seeded_survivable_plans_regenerate_and_hold_the_invariant() {
        for seed in 0..200u64 {
            let boards = 1 + (seed % 4) as usize;
            let p = ServeFaultPlan::survivable(seed, boards, 3);
            assert_eq!(p, ServeFaultPlan::survivable(seed, boards, 3));
            assert!(p.is_survivable(boards, 3), "seed {seed}: {p:?}");
            assert!(p.deaths.iter().all(|s| s.board != 0), "board 0 must survive");
        }
        assert!(ServeFaultPlan::survivable(1, 4, 3) != ServeFaultPlan::survivable(2, 4, 3));
    }

    #[test]
    fn output_checksum_detects_single_lane_flips() {
        let out = vec![5i16, -3, 0, 127];
        let base = output_checksum(&out);
        assert_eq!(base, output_checksum(&out.clone()), "not deterministic");
        let mut flipped = out.clone();
        flipped[2] ^= 1;
        assert_ne!(base, output_checksum(&flipped));
        // length is part of the word (a truncated block never matches)
        assert_ne!(output_checksum(&out[..3]), base);
    }
}
