//! **Multi-tenant batched inference serving** over the simulated board
//! pool — the runtime that turns the trainer-plus-simulator stack into a
//! system that *serves* (ROADMAP north star: heavy traffic, many nets,
//! many boards).
//!
//! Many registered [`crate::session::Artifact`]s accept concurrent
//! requests; a dynamic micro-batcher coalesces each net's queue into
//! bucket-sized micro-batches from the forward batch ladder
//! ([`crate::nn::lowering::forward_buckets`], compiled once per
//! `(net, bucket, device)` via [`crate::session::Artifact::forward_variant`]);
//! a board pool executes them on compiled
//! [`crate::hw::ExecPlan::run_forward`] engines. The whole runtime is a
//! deterministic discrete-event simulation over the machine cycle model:
//! same seed ⇒ same outputs and same metrics, and every served output is
//! **bit-identical** to a batch-1 `Session::infer` with the same
//! parameters (forward lanes are per-row; asserted by the
//! `testkit::diff` serving level and `rust/tests/serving.rs`).
//!
//! The runtime is also **SLO-aware and fault-tolerant** (degraded
//! mode): requests carry a priority and an optional deadline
//! ([`SubmitOptions`]); overload sheds the worst backlogged request
//! first; a deterministic [`ServeFaultPlan`] injects board stalls,
//! output corruption (caught by the [`output_checksum`] integrity
//! word), and deaths; boards cycle Healthy → Quarantined → probation on
//! strikes; faulted micro-batches are hedged onto the healthiest free
//! board within a bounded retry budget; and every admitted request
//! terminates as a [`Completion`] or a typed [`DroppedRequest`] — never
//! a hang or a silent drop. With an empty fault plan and default submit
//! options, behaviour is bit-identical to fault-free serving.
//!
//! * [`Server`] / [`ServeConfig`] — the runtime ([`Server::open`],
//!   `register`, `submit_at`/`submit_with`, `drain`,
//!   `take_completions`, `take_dropped`, `report`).
//!   [`crate::session::Session::server`] is the one-net convenience
//!   front door.
//! * [`batcher`] — per-net queues, flush rules (fill / wait bound /
//!   deadline urgency), bucket selection.
//! * [`fault`] — the deterministic serving fault plan and the output
//!   integrity word.
//! * [`metrics`] — per-net/per-board counters, p50/p99 simulated-cycle
//!   latency, batch-fill, shed/expired/late/retry counts, board health;
//!   table + JSON rendering.
//! * [`load`] — the seeded open-loop generators (plain and
//!   SLO-annotated) behind `mfnn serve-sim` and `bench_serving`.
//!
//! See DESIGN.md §Serving for the architecture diagram, the batching
//! semantics, the backpressure contract, the degraded-mode state
//! machine, and how serving coexists with training on the same boards
//! (`cluster::worker` `InferChunk`).

pub mod batcher;
pub mod fault;
pub mod load;
pub mod metrics;
pub mod server;

pub use batcher::{bucket_for, MicroBatcher, Pending};
pub use fault::{output_checksum, ServeFaultPlan, ServeFaultSite, StallSite};
pub use load::{open_loop, seeded_params, slo_open_loop, SloRequest, SynthRequest};
pub use metrics::{percentile, BoardMetrics, NetMetrics, ServeReport};
pub use server::{
    Completion, DropReason, DroppedRequest, NetId, RequestId, ServeConfig, ServeError, Server,
    SubmitOptions,
};
