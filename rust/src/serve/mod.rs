//! **Multi-tenant batched inference serving** over the simulated board
//! pool — the runtime that turns the trainer-plus-simulator stack into a
//! system that *serves* (ROADMAP north star: heavy traffic, many nets,
//! many boards).
//!
//! Many registered [`crate::session::Artifact`]s accept concurrent
//! requests; a dynamic micro-batcher coalesces each net's queue into
//! bucket-sized micro-batches from the forward batch ladder
//! ([`crate::nn::lowering::forward_buckets`], compiled once per
//! `(net, bucket, device)` via [`crate::session::Artifact::forward_variant`]);
//! a board pool executes them on compiled
//! [`crate::hw::ExecPlan::run_forward`] engines. The whole runtime is a
//! deterministic discrete-event simulation over the machine cycle model:
//! same seed ⇒ same outputs and same metrics, and every served output is
//! **bit-identical** to a batch-1 `Session::infer` with the same
//! parameters (forward lanes are per-row; asserted by the
//! `testkit::diff` serving level and `rust/tests/serving.rs`).
//!
//! * [`Server`] / [`ServeConfig`] — the runtime ([`Server::open`],
//!   `register`, `submit_at`, `drain`, `take_completions`, `report`).
//!   [`crate::session::Session::server`] is the one-net convenience
//!   front door.
//! * [`batcher`] — per-net queues, flush rules, bucket selection.
//! * [`metrics`] — per-net/per-board counters, p50/p99 simulated-cycle
//!   latency, batch-fill, throughput; table + JSON rendering.
//! * [`load`] — the seeded open-loop generator behind `mfnn serve-sim`
//!   and `bench_serving`.
//!
//! See DESIGN.md §Serving for the architecture diagram, the batching
//! semantics, the backpressure contract, and how serving coexists with
//! training on the same boards (`cluster::worker` `InferChunk`).

pub mod batcher;
pub mod load;
pub mod metrics;
pub mod server;

pub use batcher::{bucket_for, MicroBatcher, Pending};
pub use load::{open_loop, seeded_params, SynthRequest};
pub use metrics::{percentile, BoardMetrics, NetMetrics, ServeReport};
pub use server::{Completion, NetId, RequestId, ServeConfig, ServeError, Server};
