//! Differential fuzzing & deterministic fault-injection testkit.
//!
//! The paper's claim is that one flexible structure can train/test *any*
//! network on *any* number of FPGAs. The stack realises that at six
//! fidelity levels (float oracle → FastSim → unfused plan → fused plan →
//! cluster → serving runtime), and this subsystem *generates* the
//! scenarios that prove the levels agree — instead of trusting a handful
//! of hand-picked nets:
//!
//! * [`gen`] — seeded case generators built on [`crate::prop::Gen`]:
//!   random `MlpSpec`s with derived parameters/batches, random
//!   well-typed operator graphs ([`gen::GraphCase`]: residual / gated /
//!   CNN / transformer-block), raw vector `Program`s, datasets, and M×F
//!   cluster topologies sweeping the §2 placements, each with
//!   structured shrinkers.
//! * [`diff`] — the differential executor: every case through every
//!   level via the Session API, asserting bit-identical outputs, trained
//!   weights, and identical cycle accounting between fused and unfused
//!   plans (the float oracle gets a quantisation tolerance band). The
//!   serving level ([`Differ::run_serve`]) batches each case's rows
//!   through [`crate::serve::Server`] and asserts every served output is
//!   bit-identical to a batch-1 `Session::infer`.
//! * Fault injection — [`crate::cluster::fault::FaultPlan`] schedules
//!   deterministic worker death, post-checksum chunk corruption, and
//!   delayed/reordered replies; [`Differ::run_faults`] asserts the
//!   leader never hangs and either finishes bit-identically (recovered
//!   or benign) or surfaces a typed [`crate::cluster::ClusterError`].
//! * Recovery — [`Differ::run_recovery`] generates **survivable** fault
//!   plans (kills leave ≥ 1 board per recovery domain) and asserts the
//!   run completes with weights, curves, and stats bit-identical to the
//!   fault-free run under the default
//!   [`crate::cluster::RecoveryPolicy`] (DESIGN.md §Recovery).
//! * Serving chaos — [`Differ::run_serve_chaos`] generates survivable
//!   [`crate::serve::ServeFaultPlan`]s (board stalls, output
//!   corruption, deaths that spare board 0) against SLO-annotated
//!   request streams and asserts the degraded-mode contract: every
//!   admitted request terminates as a completion or a typed drop, no
//!   retry-budget exhaustion, completed outputs bit-identical to the
//!   batch-1 reference, the whole outcome replay-deterministic
//!   (DESIGN.md §Serving, "Degraded mode").
//! * Memory planner — [`Differ::run_memplan`] runs each generated MLP /
//!   operator-graph forward program with the static lane-reuse layout on
//!   vs off ([`crate::hw::MemPlan`]) and asserts the planner is
//!   behaviour-invisible: bit-identical non-scratch buffers, identical
//!   `RunStats` for both fused and unfused variants, and a planned
//!   arena never larger than the packed one (DESIGN.md §Memory planner).
//! * Static checker — [`Differ::run_check`] generates programs with one
//!   planted defect each (undefined-lane read, guaranteed wrap,
//!   ring-FIFO overrun, cross-lane RAW hazard) and asserts
//!   [`crate::analysis::check_program`] flags every one; checker-clean
//!   random programs must run every raw-program fidelity level and
//!   finish with every lane inside the checker's certified value ranges
//!   (DESIGN.md §Static analysis).
//! * [`fuzz`] — the harness: seeded case streams, greedy shrinking to a
//!   minimal failing case, seed replay (`mfnn fuzz --cases 1 --seed N`
//!   reproduces exactly), and corpus snapshots under
//!   `rust/tests/corpus/`.
//!
//! Reproducing a failure: every divergence prints its case seed; the
//! `mfnn fuzz` subcommand replays it, and `MFNN_PROP_CASES` scales the
//! adjacent property suites (see DESIGN.md §Testing).

pub mod diff;
pub mod fuzz;
pub mod gen;

pub use diff::{Differ, Divergence, Level};
pub use fuzz::{
    case_seed, fuzz, parse_corpus, replay_corpus, run_case, Family, FuzzFailure, FuzzOptions,
    FuzzReport,
};
pub use gen::{
    CheckCase, CheckDefect, FaultCase, FuzzCase, GraphArch, GraphCase, MemplanCase, NetCase,
    ProgramCase, RecoveryCase, ServeChaosCase,
};
