//! The differential executor: run one generated case through every
//! simulator fidelity level and assert agreement.
//!
//! Levels (DESIGN.md §Testing):
//!
//! | level | executor | agreement |
//! |---|---|---|
//! | L0 | [`FloatMlp`] / [`FloatGraph`] float64 oracle | quantisation tolerance band |
//! | L1 | [`FastSim`] sequential reference | bit-exact |
//! | L2 | unfused [`ExecPlan`], one wave/step | bit-exact + same `RunStats` |
//! | L3 | fused [`ExecPlan`] via the Session API | bit-exact + same `RunStats` |
//! | L4 | cluster runtime (`leader::execute`) | bit-exact weights vs board |
//! | L5 | serving runtime ([`crate::serve::Server`]) | bit-exact vs batch-1 infer |
//!
//! The float oracle cannot be bit-exact against a 16-bit datapath; it is
//! the wiring sanity check (a transposed weight or dropped layer shows up
//! as an O(1) deviation, quantisation as an O(resolution) one). All
//! fixed-point levels must agree to the bit, including cycle accounting
//! between the fused and unfused plans.

use super::gen::{
    CheckCase, CheckDefect, FaultCase, FuzzCase, GraphCase, MemplanCase, NetCase, ProgramCase,
    RecoveryCase, ServeChaosCase,
};
use crate::analysis::{check_program, CheckLevel, CheckOptions};
use crate::assembler::program::{BufKind, Step};
use crate::cluster::cost::SyncPolicy;
use crate::cluster::fault::FaultPlan;
use crate::cluster::leader::{self, ClusterConfig, ClusterError, Job, JobResult};
use crate::hw::{ExecPlan, FastSim, FpgaDevice, MatrixMachine, MemPlan};
use crate::nn::float_ref::FloatMlp;
use crate::nn::graph::{lower_graph_forward, lower_mlp_forward, lower_mlp_train, FloatGraph};
use crate::nn::trainer::Trainer;
use crate::session::{CompileOptions, Compiler, Session, Target};
use std::sync::Arc;

/// Float-oracle tolerance per layer: generous against quantisation +
/// LUT approximation (both O(2^-frac_bits) at the generated magnitudes),
/// tight against wiring bugs (O(1) deviations).
const FLOAT_TOL_PER_LAYER: f64 = 0.35;

/// Which differential level a divergence was detected at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// L0: `nn::float_ref` float64 oracle.
    FloatRef,
    /// L1: `hw::FastSim` sequential functional reference.
    FastSim,
    /// L2: unfused `ExecPlan` (incl. structural microcode verification).
    UnfusedPlan,
    /// L3: fused `ExecPlan` — the production hot path.
    FusedPlan,
    /// L4: multi-FPGA cluster runtime.
    Cluster,
    /// L5: multi-tenant batched serving runtime.
    Serve,
    /// Memory-planner differential: planned vs packed `ExecPlan` layout.
    MemPlan,
    /// Static-checker differential: planted defects caught, clean
    /// programs executed within the certified value ranges.
    Check,
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Level::FloatRef => "float_ref",
            Level::FastSim => "fastsim",
            Level::UnfusedPlan => "unfused_plan",
            Level::FusedPlan => "fused_plan",
            Level::Cluster => "cluster",
            Level::Serve => "serve",
            Level::MemPlan => "memplan",
            Level::Check => "check",
        })
    }
}

/// A detected cross-level disagreement (or a harness error on a
/// generated case — also a bug, and also shrinkable).
#[derive(Debug, Clone)]
pub struct Divergence {
    /// Level at which the disagreement was detected.
    pub level: Level,
    /// What disagreed.
    pub what: String,
}

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.level, self.what)
    }
}

fn fail(level: Level, what: impl Into<String>) -> Divergence {
    Divergence { level, what: what.into() }
}

/// Render the first differing lane of two supposedly-identical vectors.
fn first_diff(a: &[i16], b: &[i16]) -> String {
    if a.len() != b.len() {
        return format!("lengths {} vs {}", a.len(), b.len());
    }
    match a.iter().zip(b).position(|(x, y)| x != y) {
        Some(i) => format!("lane {i}: {} vs {}", a[i], b[i]),
        None => "equal".into(),
    }
}

/// The differential executor. Owns a [`Compiler`] so shrink replays of
/// the same net reuse cached artifacts and plans.
pub struct Differ {
    /// Board part every level simulates.
    pub device: FpgaDevice,
    /// Test-only hook: corrupt one FastSim output lane so the
    /// catch→shrink→replay pipeline can be exercised on demand
    /// (`mfnn fuzz --plant-divergence`; asserted by `tests/testkit.rs`).
    pub plant_divergence: bool,
    compiler: Compiler,
}

impl Default for Differ {
    fn default() -> Differ {
        Differ::new(FpgaDevice::selected())
    }
}

impl Differ {
    /// A differ simulating `device` at every level.
    pub fn new(device: FpgaDevice) -> Differ {
        Differ { device, plant_divergence: false, compiler: Compiler::new() }
    }

    /// Enable the test-only planted divergence.
    pub fn with_plant(mut self, plant: bool) -> Differ {
        self.plant_divergence = plant;
        self
    }

    fn cluster_config(
        &self,
        boards: usize,
        sync_every: usize,
        sync: SyncPolicy,
        faults: FaultPlan,
    ) -> ClusterConfig {
        ClusterConfig {
            boards,
            device: self.device.part.name.to_string(),
            sync_every,
            sync,
            faults,
            ..ClusterConfig::default()
        }
    }

    // ------------------------------------------------------------ forward

    /// Forward differential: one inference batch through L0–L3.
    pub fn run_net(&self, c: &NetCase) -> Result<(), Divergence> {
        let spec = c.spec();
        let fixed = c.fixed();
        let (qw, qb) = c.params();
        let qx = c.input();
        let lowered = lower_mlp_forward(&spec, c.batch)
            .map_err(|e| fail(Level::FastSim, format!("lowering failed: {e}")))?;
        let program = &lowered.program;

        // L1: FastSim, the sequential functional reference.
        let mut sim = FastSim::new(program);
        sim.set_buffer(lowered.x, &qx);
        for l in 0..spec.layers.len() {
            sim.set_buffer(lowered.weights[l], &qw[l]);
            sim.set_buffer(lowered.biases[l], &qb[l]);
        }
        for step in &program.steps {
            if let Step::Wave(w) = step {
                sim.exec_wave(program, w);
            }
        }
        let mut fast_out = sim.buffer(lowered.out).to_vec();
        if self.plant_divergence {
            if let Some(v) = fast_out.last_mut() {
                *v ^= 1;
            }
        }

        // L3: fused plan through the Session front door.
        let artifact = self
            .compiler
            .compile_spec(&spec, &CompileOptions::inference(c.batch))
            .map_err(|e| fail(Level::FusedPlan, format!("compile failed: {e}")))?;
        let mut session = Session::open(Arc::clone(&artifact), Target::Board(self.device))
            .map_err(|e| fail(Level::FusedPlan, format!("open failed: {e}")))?;
        for l in 0..spec.layers.len() {
            for (name, data) in [(format!("w{l}"), &qw[l]), (format!("b{l}"), &qb[l])] {
                let h = artifact
                    .tensor(&name)
                    .map_err(|e| fail(Level::FusedPlan, format!("handle {name}: {e}")))?;
                session
                    .write(&h, data)
                    .map_err(|e| fail(Level::FusedPlan, format!("write {name}: {e}")))?;
            }
        }
        let inf = session
            .infer(&qx)
            .map_err(|e| fail(Level::FusedPlan, format!("infer failed: {e}")))?;
        if inf.output != fast_out {
            return Err(fail(
                Level::FusedPlan,
                format!(
                    "forward output, fused plan vs FastSim: {}",
                    first_diff(&inf.output, &fast_out)
                ),
            ));
        }

        // L2: the unfused plan on the same bindings.
        let unfused = ExecPlan::new_unfused(program, &self.device);
        let mut st = unfused.state();
        unfused.write_buffer(&mut st, lowered.x, &qx);
        for l in 0..spec.layers.len() {
            unfused.write_buffer(&mut st, lowered.weights[l], &qw[l]);
            unfused.write_buffer(&mut st, lowered.biases[l], &qb[l]);
        }
        let unfused_stats = unfused.execute(&mut st);
        let unfused_out = unfused.read_buffer(&st, lowered.out);
        if unfused_out != fast_out.as_slice() {
            return Err(fail(
                Level::UnfusedPlan,
                format!(
                    "forward output, unfused plan vs FastSim: {}",
                    first_diff(unfused_out, &fast_out)
                ),
            ));
        }

        // L3 cycle accounting + structural microcode verification: the
        // fused machine and a structurally-verified clone must agree with
        // each other and with the standalone unfused plan.
        let mut fused_m = MatrixMachine::new(self.device, program)
            .map_err(|e| fail(Level::FusedPlan, format!("machine build failed: {e}")))?;
        fused_m.write_id(lowered.x, &qx).expect("shape checked");
        for l in 0..spec.layers.len() {
            fused_m.write_id(lowered.weights[l], &qw[l]).expect("shape checked");
            fused_m.write_id(lowered.biases[l], &qb[l]).expect("shape checked");
        }
        let mut verif_m = fused_m.clone();
        let fused_stats = fused_m.execute();
        let verif_stats = verif_m
            .execute_verified()
            .map_err(|e| fail(Level::UnfusedPlan, format!("structural verification: {e}")))?;
        if fused_m.read_id(lowered.out) != verif_m.read_id(lowered.out) {
            return Err(fail(
                Level::UnfusedPlan,
                format!(
                    "forward output, fused vs structurally-verified: {}",
                    first_diff(fused_m.read_id(lowered.out), verif_m.read_id(lowered.out))
                ),
            ));
        }
        if fused_stats != verif_stats {
            return Err(fail(
                Level::UnfusedPlan,
                format!("cycle accounting, fused vs unfused: {fused_stats:?} vs {verif_stats:?}"),
            ));
        }
        if fused_stats != unfused_stats {
            return Err(fail(
                Level::UnfusedPlan,
                format!(
                    "cycle accounting, fused vs standalone unfused plan: \
                     {fused_stats:?} vs {unfused_stats:?}"
                ),
            ));
        }

        // L0: float64 oracle within the quantisation tolerance band.
        let float = FloatMlp {
            spec: spec.clone(),
            weights: qw.iter().map(|w| fixed.decode_vec(w)).collect(),
            biases: qb.iter().map(|b| fixed.decode_vec(b)).collect(),
        };
        let (in_dim, out_dim) = (spec.input_dim(), spec.output_dim());
        let tol = FLOAT_TOL_PER_LAYER * spec.layers.len() as f64;
        for row in 0..c.batch {
            let x = fixed.decode_vec(&qx[row * in_dim..(row + 1) * in_dim]);
            let want = float.forward(&x);
            for j in 0..out_dim {
                let got = fixed.to_f64(fast_out[row * out_dim + j]);
                if (got - want[j]).abs() > tol {
                    return Err(fail(
                        Level::FloatRef,
                        format!(
                            "row {row} output {j}: fixed {got} vs float {:.4} (tol {tol})",
                            want[j]
                        ),
                    ));
                }
            }
        }
        Ok(())
    }

    /// Graph forward differential: one inference batch of a generated
    /// operator graph (residual / gated / CNN / transformer-block)
    /// through L0–L3 — same ladder as [`Differ::run_net`], with
    /// [`FloatGraph`] as the L0 oracle and
    /// [`crate::session::Compiler::compile_graph`] as the front door.
    pub fn run_graph(&self, c: &GraphCase) -> Result<(), Divergence> {
        let spec = c.spec();
        let fixed = c.fixed();
        let (qw, qb) = c.params();
        let qx = c.input();
        let decls = spec.param_decls().expect("generated graphs are valid");
        let lowered = lower_graph_forward(&spec, c.batch)
            .map_err(|e| fail(Level::FastSim, format!("graph lowering failed: {e}")))?;
        let program = &lowered.program;

        // L1: FastSim, the sequential functional reference.
        let mut sim = FastSim::new(program);
        sim.set_buffer(lowered.x, &qx);
        for i in 0..decls.len() {
            sim.set_buffer(lowered.weights[i], &qw[i]);
            sim.set_buffer(lowered.biases[i], &qb[i]);
        }
        for step in &program.steps {
            if let Step::Wave(w) = step {
                sim.exec_wave(program, w);
            }
        }
        let mut fast_out = sim.buffer(lowered.out).to_vec();
        if self.plant_divergence {
            if let Some(v) = fast_out.last_mut() {
                *v ^= 1;
            }
        }

        // L3: fused plan through the Session front door.
        let artifact = self
            .compiler
            .compile_graph(&spec, &CompileOptions::inference(c.batch))
            .map_err(|e| fail(Level::FusedPlan, format!("graph compile failed: {e}")))?;
        let mut session = Session::open(Arc::clone(&artifact), Target::Board(self.device))
            .map_err(|e| fail(Level::FusedPlan, format!("open failed: {e}")))?;
        for (i, d) in decls.iter().enumerate() {
            for (name, data) in [(&d.wname, &qw[i]), (&d.bname, &qb[i])] {
                let h = artifact
                    .tensor(name)
                    .map_err(|e| fail(Level::FusedPlan, format!("handle {name}: {e}")))?;
                session
                    .write(&h, data)
                    .map_err(|e| fail(Level::FusedPlan, format!("write {name}: {e}")))?;
            }
        }
        let inf = session
            .infer(&qx)
            .map_err(|e| fail(Level::FusedPlan, format!("infer failed: {e}")))?;
        if inf.output != fast_out {
            return Err(fail(
                Level::FusedPlan,
                format!(
                    "graph output, fused plan vs FastSim: {}",
                    first_diff(&inf.output, &fast_out)
                ),
            ));
        }

        // L2: the unfused plan on the same bindings.
        let unfused = ExecPlan::new_unfused(program, &self.device);
        let mut st = unfused.state();
        unfused.write_buffer(&mut st, lowered.x, &qx);
        for i in 0..decls.len() {
            unfused.write_buffer(&mut st, lowered.weights[i], &qw[i]);
            unfused.write_buffer(&mut st, lowered.biases[i], &qb[i]);
        }
        let unfused_stats = unfused.execute(&mut st);
        let unfused_out = unfused.read_buffer(&st, lowered.out);
        if unfused_out != fast_out.as_slice() {
            return Err(fail(
                Level::UnfusedPlan,
                format!(
                    "graph output, unfused plan vs FastSim: {}",
                    first_diff(unfused_out, &fast_out)
                ),
            ));
        }

        // L3 cycle accounting + structural microcode verification.
        let mut fused_m = MatrixMachine::new(self.device, program)
            .map_err(|e| fail(Level::FusedPlan, format!("machine build failed: {e}")))?;
        fused_m.write_id(lowered.x, &qx).expect("shape checked");
        for i in 0..decls.len() {
            fused_m.write_id(lowered.weights[i], &qw[i]).expect("shape checked");
            fused_m.write_id(lowered.biases[i], &qb[i]).expect("shape checked");
        }
        let mut verif_m = fused_m.clone();
        let fused_stats = fused_m.execute();
        let verif_stats = verif_m
            .execute_verified()
            .map_err(|e| fail(Level::UnfusedPlan, format!("structural verification: {e}")))?;
        if fused_m.read_id(lowered.out) != verif_m.read_id(lowered.out) {
            return Err(fail(
                Level::UnfusedPlan,
                format!(
                    "graph output, fused vs structurally-verified: {}",
                    first_diff(fused_m.read_id(lowered.out), verif_m.read_id(lowered.out))
                ),
            ));
        }
        if fused_stats != verif_stats {
            return Err(fail(
                Level::UnfusedPlan,
                format!("cycle accounting, fused vs unfused: {fused_stats:?} vs {verif_stats:?}"),
            ));
        }
        if fused_stats != unfused_stats {
            return Err(fail(
                Level::UnfusedPlan,
                format!(
                    "cycle accounting, fused vs standalone unfused plan: \
                     {fused_stats:?} vs {unfused_stats:?}"
                ),
            ));
        }

        // L0: FloatGraph oracle. Tolerance scales with op depth;
        // attention weighs as five units (q/k/v/o projections + the
        // Exp/Recip softmax), normalisation as two (Rsqrt amplifies
        // quantisation error near small variances).
        let float = FloatGraph {
            spec: spec.clone(),
            params: qw
                .iter()
                .zip(&qb)
                .map(|(w, b)| (fixed.decode_vec(w), fixed.decode_vec(b)))
                .collect(),
        };
        let units: usize = spec
            .ops
            .iter()
            .map(|op| match op.kind {
                crate::nn::graph::OpKind::Attention { .. } => 5,
                crate::nn::graph::OpKind::Normalization { .. } => 2,
                _ => 1,
            })
            .sum();
        let tol = FLOAT_TOL_PER_LAYER * units as f64;
        let (in_dim, out_dim) = (spec.input_dim(), spec.output_dim());
        for row in 0..c.batch {
            let x = fixed.decode_vec(&qx[row * in_dim..(row + 1) * in_dim]);
            let want = float.forward(&x);
            for j in 0..out_dim {
                let got = fixed.to_f64(fast_out[row * out_dim + j]);
                if (got - want[j]).abs() > tol {
                    return Err(fail(
                        Level::FloatRef,
                        format!(
                            "row {row} output {j}: fixed {got} vs float {:.4} (tol {tol})",
                            want[j]
                        ),
                    ));
                }
            }
        }
        Ok(())
    }

    // ----------------------------------------------------------- training

    /// Training differential: bare engine vs Session(board) vs a 1-board
    /// cluster must produce bit-identical trained weights and loss
    /// curves; one training step must verify structurally with identical
    /// cycle accounting.
    pub fn run_train(&self, c: &FuzzCase) -> Result<(), Divergence> {
        let spec = c.net.spec();
        let cfg = c.train_config();
        let ds = c.dataset();

        // Engine level: the bare Trainer (what every cluster worker runs).
        let mut engine = Trainer::build(spec.clone(), self.device, cfg.clone())
            .map_err(|e| fail(Level::FusedPlan, format!("trainer build failed: {e}")))?;
        let engine_report = engine
            .train(&ds)
            .map_err(|e| fail(Level::FusedPlan, format!("engine train failed: {e}")))?;
        let (ew, eb) = engine.weights();

        // Session front door on a board target.
        let artifact = self
            .compiler
            .compile_spec(&spec, &CompileOptions::training(cfg.batch, cfg.lr))
            .map_err(|e| fail(Level::FusedPlan, format!("compile failed: {e}")))?;
        let mut session = Session::open(Arc::clone(&artifact), Target::Board(self.device))
            .map_err(|e| fail(Level::FusedPlan, format!("open failed: {e}")))?;
        let summary = session
            .train(&ds, &cfg)
            .map_err(|e| fail(Level::FusedPlan, format!("session train failed: {e}")))?;
        let (sw, sb) = session.weights().expect("trainable session");
        if sw != ew || sb != eb {
            return Err(fail(
                Level::FusedPlan,
                format!(
                    "trained weights, Session(board) vs engine: {}",
                    first_diff(&sw.concat(), &ew.concat())
                ),
            ));
        }
        if summary.curve != engine_report.curve {
            return Err(fail(
                Level::FusedPlan,
                "loss curve, Session(board) vs engine".to_string(),
            ));
        }

        // Cluster level, single board: must match the board bit-exactly.
        let job = Job {
            name: spec.name.clone(),
            spec: spec.clone(),
            cfg: cfg.clone(),
            train_data: Arc::new(ds.clone()),
            test_data: Arc::new(ds.clone()),
            initial: None,
            resume: None,
        };
        let ccfg = self.cluster_config(1, c.sync_every, c.sync, FaultPlan::none());
        let report = leader::execute(&ccfg, std::slice::from_ref(&job))
            .map_err(|e| fail(Level::Cluster, format!("1-board cluster failed: {e}")))?;
        let jr = &report.results[0];
        if jr.weights != ew || jr.biases != eb {
            return Err(fail(
                Level::Cluster,
                format!(
                    "trained weights, 1-board cluster vs board: {}",
                    first_diff(&jr.weights.concat(), &ew.concat())
                ),
            ));
        }
        if jr.curve != engine_report.curve {
            return Err(fail(Level::Cluster, "loss curve, 1-board cluster vs board".to_string()));
        }

        // One training step, fused vs structurally-verified unfused:
        // identical post-step parameters and identical cycle accounting.
        let lowered = lower_mlp_train(&spec, cfg.batch, cfg.lr)
            .map_err(|e| fail(Level::UnfusedPlan, format!("train lowering failed: {e}")))?;
        let (qw, qb) = c.net.params();
        let mut fast = MatrixMachine::new(self.device, &lowered.program)
            .map_err(|e| fail(Level::FusedPlan, format!("train machine build failed: {e}")))?;
        fast.write_id(lowered.x, &c.net.input()).expect("shape checked");
        fast.write_id(lowered.y.expect("train program declares targets"), &c.net.targets())
            .expect("shape checked");
        for l in 0..spec.layers.len() {
            fast.write_id(lowered.weights[l], &qw[l]).expect("shape checked");
            fast.write_id(lowered.biases[l], &qb[l]).expect("shape checked");
        }
        let mut slow = fast.clone();
        let sf = fast.execute();
        let sv = slow
            .execute_verified()
            .map_err(|e| fail(Level::UnfusedPlan, format!("train-step verification: {e}")))?;
        if sf != sv {
            return Err(fail(
                Level::UnfusedPlan,
                format!("train-step cycle accounting, fused vs unfused: {sf:?} vs {sv:?}"),
            ));
        }
        for l in 0..spec.layers.len() {
            if fast.read_id(lowered.weights[l]) != slow.read_id(lowered.weights[l]) {
                return Err(fail(
                    Level::UnfusedPlan,
                    format!(
                        "train-step weights layer {l}, fused vs structural: {}",
                        first_diff(
                            fast.read_id(lowered.weights[l]),
                            slow.read_id(lowered.weights[l])
                        )
                    ),
                ));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------ serving

    /// Serving differential: the batched multi-tenant serving runtime
    /// must return, for every request, exactly the lanes a sequential
    /// batch-1 [`Session::infer`] produces with the same parameters —
    /// micro-batching, bucket padding, and board placement must never
    /// change a single bit.
    pub fn run_serve(&self, c: &FuzzCase) -> Result<(), Divergence> {
        use crate::serve::{ServeConfig, Server};
        let spec = c.net.spec();
        let (qw, qb) = c.net.params();
        let qx = c.net.input();
        let in_dim = spec.input_dim();

        // Sequential reference: one batch-1 infer per request row.
        let a1 = self
            .compiler
            .compile_spec(&spec, &CompileOptions::inference(1))
            .map_err(|e| fail(Level::Serve, format!("batch-1 compile failed: {e}")))?;
        let mut reference = Session::open(Arc::clone(&a1), Target::Board(self.device))
            .map_err(|e| fail(Level::Serve, format!("reference open failed: {e}")))?;
        for l in 0..spec.layers.len() {
            for (name, data) in [(format!("w{l}"), &qw[l]), (format!("b{l}"), &qb[l])] {
                let h = a1
                    .tensor(&name)
                    .map_err(|e| fail(Level::Serve, format!("handle {name}: {e}")))?;
                reference
                    .write(&h, data)
                    .map_err(|e| fail(Level::Serve, format!("write {name}: {e}")))?;
            }
        }
        let mut want = Vec::with_capacity(c.net.batch);
        for row in qx.chunks(in_dim) {
            want.push(
                reference
                    .infer(row)
                    .map_err(|e| fail(Level::Serve, format!("reference infer: {e}")))?
                    .output,
            );
        }

        // The serving runtime: same rows as staggered requests,
        // micro-batched over the case's board pool.
        let max_batch = c.net.batch.max(2);
        let artifact = self
            .compiler
            .compile_spec(&spec, &CompileOptions::serving(max_batch))
            .map_err(|e| fail(Level::Serve, format!("serving compile failed: {e}")))?;
        let cfg = ServeConfig {
            boards: c.boards,
            device: self.device.part.name.to_string(),
            max_batch,
            max_wait_cycles: c.sync_every as u64 * 7,
            queue_cap: c.net.batch * 4 + 8,
            ..ServeConfig::default()
        };
        let mut server = Server::open(cfg)
            .map_err(|e| fail(Level::Serve, format!("server open failed: {e}")))?;
        let nid = server
            .register(Arc::clone(&artifact), &qw, &qb)
            .map_err(|e| fail(Level::Serve, format!("register failed: {e}")))?;
        for (i, row) in qx.chunks(in_dim).enumerate() {
            let at = i as u64 * (1 + c.net.seed % 5);
            server
                .submit_at(at, nid, row)
                .map_err(|e| fail(Level::Serve, format!("submit {i} failed: {e}")))?;
        }
        server.drain().map_err(|e| fail(Level::Serve, format!("drain failed: {e}")))?;
        let mut got = server.take_completions();
        got.sort_by_key(|r| r.id);
        if got.len() != want.len() {
            return Err(fail(
                Level::Serve,
                format!("{} completion(s) for {} request(s)", got.len(), want.len()),
            ));
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            if g.output != *w {
                return Err(fail(
                    Level::Serve,
                    format!(
                        "request {i} (bucket {}): served output vs batch-1 Session::infer: {}",
                        g.bucket,
                        first_diff(&g.output, w)
                    ),
                ));
            }
        }
        Ok(())
    }

    /// Serving-chaos differential — the degraded-mode acceptance
    /// property: under a **survivable** [`crate::serve::ServeFaultPlan`]
    /// (kills leave ≥ 1 board, transient sites within the hedged-retry
    /// budget) every admitted SLO-annotated request must terminate as a
    /// completion or a typed drop (never a hang, a silent loss, or a
    /// retry-budget exhaustion), every *completed* output must still be
    /// bit-identical to the batch-1 sequential reference, and the whole
    /// outcome — completions, drop records, and the metrics snapshot —
    /// must replay deterministically.
    pub fn run_serve_chaos(&self, sc: &ServeChaosCase) -> Result<(), Divergence> {
        use super::gen::SERVE_CHAOS_RETRIES;
        use crate::serve::{
            Completion, DropReason, DroppedRequest, RequestId, ServeConfig, ServeError, Server,
            SubmitOptions,
        };
        use crate::util::Rng;
        use std::collections::BTreeSet;
        let c = &sc.case;
        let spec = c.net.spec();
        let (qw, qb) = c.net.params();
        let qx = c.net.input();
        let in_dim = spec.input_dim();

        // Sequential reference: one batch-1 infer per request row
        // (identical to `run_serve`'s).
        let a1 = self
            .compiler
            .compile_spec(&spec, &CompileOptions::inference(1))
            .map_err(|e| fail(Level::Serve, format!("batch-1 compile failed: {e}")))?;
        let mut reference = Session::open(Arc::clone(&a1), Target::Board(self.device))
            .map_err(|e| fail(Level::Serve, format!("reference open failed: {e}")))?;
        for l in 0..spec.layers.len() {
            for (name, data) in [(format!("w{l}"), &qw[l]), (format!("b{l}"), &qb[l])] {
                let h = a1
                    .tensor(&name)
                    .map_err(|e| fail(Level::Serve, format!("handle {name}: {e}")))?;
                reference
                    .write(&h, data)
                    .map_err(|e| fail(Level::Serve, format!("write {name}: {e}")))?;
            }
        }
        let mut want = Vec::with_capacity(c.net.batch);
        for row in qx.chunks(in_dim) {
            want.push(
                reference
                    .infer(row)
                    .map_err(|e| fail(Level::Serve, format!("reference infer: {e}")))?
                    .output,
            );
        }

        // SLO annotations: a salted seed stream assigns each request a
        // priority and (half the time) a feasible-at-submit deadline —
        // deadlines may still expire while batches wait out faults,
        // which is exactly the degraded-mode path under test.
        let arrivals: Vec<u64> =
            (0..want.len()).map(|i| i as u64 * (1 + c.net.seed % 5)).collect();
        let opts: Vec<SubmitOptions> = {
            let mut r = Rng::new(c.net.seed ^ 0xC4A0_5D1B_54A3_2D19);
            arrivals
                .iter()
                .map(|&at| SubmitOptions {
                    priority: r.gen_range(3) as u8,
                    deadline: if r.gen_bool(0.5) {
                        Some(at + 64 + r.gen_range(4096))
                    } else {
                        None
                    },
                })
                .collect()
        };

        let max_batch = c.net.batch.max(2);
        let artifact = self
            .compiler
            .compile_spec(&spec, &CompileOptions::serving(max_batch))
            .map_err(|e| fail(Level::Serve, format!("serving compile failed: {e}")))?;
        let cfg = ServeConfig {
            boards: c.boards,
            device: self.device.part.name.to_string(),
            max_batch,
            max_wait_cycles: c.sync_every as u64 * 7,
            queue_cap: c.net.batch * 4 + 8,
            faults: sc.plan.clone(),
            max_retries: SERVE_CHAOS_RETRIES,
            ..ServeConfig::default()
        };

        // Two identical runs: the second is the replay-determinism
        // check.
        let mut runs: Vec<(Vec<(RequestId, usize)>, Vec<Completion>, Vec<DroppedRequest>, String)> =
            Vec::with_capacity(2);
        for rep in 0..2 {
            let mut server = Server::open(cfg.clone())
                .map_err(|e| fail(Level::Serve, format!("run {rep}: server open failed: {e}")))?;
            let nid = server
                .register(Arc::clone(&artifact), &qw, &qb)
                .map_err(|e| fail(Level::Serve, format!("run {rep}: register failed: {e}")))?;
            let mut admitted: Vec<(RequestId, usize)> = Vec::new();
            for (i, row) in qx.chunks(in_dim).enumerate() {
                match server.submit_with(arrivals[i], nid, row, opts[i]) {
                    Ok(id) => admitted.push((id, i)),
                    // Typed refusals are legitimate degraded-mode
                    // outcomes; anything else is a harness bug.
                    Err(ServeError::Shed { .. }) | Err(ServeError::DeadlineExceeded { .. }) => {}
                    Err(e) => {
                        return Err(fail(
                            Level::Serve,
                            format!("run {rep}: submit {i} failed untyped: {e}"),
                        ))
                    }
                }
            }
            server
                .drain()
                .map_err(|e| fail(Level::Serve, format!("run {rep}: drain failed: {e}")))?;
            let completions = server.take_completions();
            let dropped = server.take_dropped();
            let json = server.report().to_json();
            runs.push((admitted, completions, dropped, json));
        }
        let (admitted, completions, dropped, json) = &runs[0];

        // No silent losses, no double deliveries: every admitted id
        // terminates exactly once, as a completion or a typed drop.
        let admitted_ids: BTreeSet<RequestId> = admitted.iter().map(|&(id, _)| id).collect();
        let mut seen: BTreeSet<RequestId> = BTreeSet::new();
        for id in completions
            .iter()
            .map(|g| g.id)
            .chain(dropped.iter().map(|d| d.id))
        {
            if !admitted_ids.contains(&id) {
                return Err(fail(Level::Serve, format!("request {id} terminated twice or was never admitted")));
            }
            if !seen.insert(id) {
                return Err(fail(Level::Serve, format!("request {id} terminated twice")));
            }
        }
        if seen != admitted_ids {
            let missing = admitted_ids.difference(&seen).count();
            return Err(fail(
                Level::Serve,
                format!("{missing} admitted request(s) silently lost under the fault plan"),
            ));
        }
        // A survivable plan never exhausts the hedged-retry budget.
        if let Some(d) = dropped.iter().find(|d| d.reason == DropReason::RetryBudget) {
            return Err(fail(
                Level::Serve,
                format!("request {} exhausted retries under a survivable plan", d.id),
            ));
        }
        // Completed outputs are still bit-identical to the batch-1
        // reference — faults and hedging must never corrupt a result.
        let index_of: std::collections::BTreeMap<RequestId, usize> =
            admitted.iter().map(|&(id, i)| (id, i)).collect();
        for g in completions {
            let i = index_of[&g.id];
            if g.output != want[i] {
                return Err(fail(
                    Level::Serve,
                    format!(
                        "request {i} (bucket {}): chaos-served output vs batch-1 \
                         Session::infer: {}",
                        g.bucket,
                        first_diff(&g.output, &want[i])
                    ),
                ));
            }
        }
        // Replay determinism: same seed + same plan ⇒ identical
        // admissions, completions, drop records, and metrics snapshot.
        let (admitted2, completions2, dropped2, json2) = &runs[1];
        if admitted != admitted2 {
            return Err(fail(Level::Serve, "admission set nondeterministic across replays"));
        }
        if format!("{completions:?}") != format!("{completions2:?}") {
            return Err(fail(Level::Serve, "completions nondeterministic across replays"));
        }
        if dropped != dropped2 {
            return Err(fail(Level::Serve, "drop records nondeterministic across replays"));
        }
        if json != json2 {
            return Err(fail(Level::Serve, "metrics snapshot nondeterministic across replays"));
        }
        Ok(())
    }

    // ------------------------------------------------------------ cluster

    /// Build the case's M jobs (same net, decorrelated seeds).
    fn jobs_for(&self, c: &FuzzCase) -> Vec<Job> {
        let spec = c.net.spec();
        let ds = Arc::new(c.dataset());
        (0..c.jobs)
            .map(|j| {
                let mut cfg = c.train_config();
                cfg.seed = cfg.seed.wrapping_add(j as u64);
                Job {
                    name: format!("{}-{j}", spec.name),
                    spec: spec.clone(),
                    cfg,
                    train_data: Arc::clone(&ds),
                    test_data: Arc::clone(&ds),
                    initial: None,
                    resume: None,
                }
            })
            .collect()
    }

    /// Cluster differential: the M×F topology must schedule per §2, run
    /// deterministically (bit-identical results across two executions),
    /// and a cluster-target Session must adopt exactly the weights the
    /// engine produces. Every comparison here is same-policy vs
    /// same-policy, so all [`SyncPolicy`] variants — including
    /// `BoundedStale` — are held to the bit-exact replay bar.
    pub fn run_cluster(&self, c: &FuzzCase) -> Result<(), Divergence> {
        use crate::cluster::scheduler::PlacementMode;
        let jobs = self.jobs_for(c);
        let ccfg = self.cluster_config(c.boards, c.sync_every, c.sync, FaultPlan::none());
        let r1 = leader::execute(&ccfg, &jobs)
            .map_err(|e| fail(Level::Cluster, format!("cluster failed: {e}")))?;
        let r2 = leader::execute(&ccfg, &jobs)
            .map_err(|e| fail(Level::Cluster, format!("cluster replay failed: {e}")))?;

        let want_mode = if c.jobs == c.boards {
            PlacementMode::OneToOne
        } else if c.jobs > c.boards {
            PlacementMode::Sequential
        } else {
            PlacementMode::Divided
        };
        if r1.placement.mode != want_mode {
            return Err(fail(
                Level::Cluster,
                format!(
                    "placement mode {:?} for M={} F={}, want {want_mode:?}",
                    r1.placement.mode, c.jobs, c.boards
                ),
            ));
        }
        if r1.placement != r2.placement {
            return Err(fail(Level::Cluster, "placement nondeterministic".to_string()));
        }
        if r1.makespan_s != r2.makespan_s {
            return Err(fail(Level::Cluster, "makespan nondeterministic".to_string()));
        }
        for (a, b) in r1.results.iter().zip(&r2.results) {
            if let Err(d) = job_results_equal(a, b) {
                return Err(fail(
                    Level::Cluster,
                    format!("nondeterministic result for job {:?}: {d}", a.name),
                ));
            }
        }

        // Session on a cluster target adopts exactly the engine's weights.
        let spec = c.net.spec();
        let cfg = c.train_config();
        let ds = c.dataset();
        let single = Job {
            name: spec.name.clone(),
            spec: spec.clone(),
            cfg: cfg.clone(),
            train_data: Arc::new(ds.clone()),
            test_data: Arc::new(ds.clone()),
            initial: None,
            resume: None,
        };
        let want = leader::execute(&ccfg, std::slice::from_ref(&single))
            .map_err(|e| fail(Level::Cluster, format!("reference cluster failed: {e}")))?;
        let artifact = self
            .compiler
            .compile_spec(&spec, &CompileOptions::training(cfg.batch, cfg.lr))
            .map_err(|e| fail(Level::Cluster, format!("compile failed: {e}")))?;
        let mut cs = Session::open(Arc::clone(&artifact), Target::Cluster(ccfg))
            .map_err(|e| fail(Level::Cluster, format!("cluster session open failed: {e}")))?;
        cs.train(&ds, &cfg)
            .map_err(|e| fail(Level::Cluster, format!("cluster session train failed: {e}")))?;
        let (cw, cb) = cs.weights().expect("trainable session");
        if cw != want.results[0].weights || cb != want.results[0].biases {
            return Err(fail(
                Level::Cluster,
                format!(
                    "adopted weights, cluster Session vs engine: {}",
                    first_diff(&cw.concat(), &want.results[0].weights.concat())
                ),
            ));
        }
        Ok(())
    }

    // ------------------------------------------------------- raw programs

    /// Raw-program differential: FastSim vs unfused vs fused vs
    /// structural, over every buffer and the full
    /// [`crate::hw::RunStats`].
    pub fn run_program(&self, c: &ProgramCase) -> Result<(), Divergence> {
        let (p, binds) = c.build();
        p.check()
            .map_err(|e| fail(Level::FastSim, format!("generated program invalid: {e}")))?;

        // L1: FastSim.
        let mut sim = FastSim::new(&p);
        for (id, data) in &binds {
            sim.set_buffer(*id, data);
        }
        for step in &p.steps {
            if let Step::Wave(w) = step {
                sim.exec_wave(&p, w);
            }
        }

        // L3 fused + structural clone.
        let mut fast = MatrixMachine::new(self.device, &p)
            .map_err(|e| fail(Level::FusedPlan, format!("machine build failed: {e}")))?;
        for (id, data) in &binds {
            fast.write_id(*id, data).expect("shape checked");
        }
        let mut slow = fast.clone();
        let sf = fast.execute();
        let sv = slow
            .execute_verified()
            .map_err(|e| fail(Level::UnfusedPlan, format!("structural verification: {e}")))?;
        if sf != sv {
            return Err(fail(
                Level::UnfusedPlan,
                format!("cycle accounting, fused vs unfused: {sf:?} vs {sv:?}"),
            ));
        }

        // L2 standalone unfused plan.
        let unfused = ExecPlan::new_unfused(&p, &self.device);
        let mut st = unfused.state();
        for (id, data) in &binds {
            unfused.write_buffer(&mut st, *id, data);
        }
        let su = unfused.execute(&mut st);
        if su != sf {
            return Err(fail(
                Level::UnfusedPlan,
                format!("cycle accounting, standalone unfused vs fused: {su:?} vs {sf:?}"),
            ));
        }

        for id in 0..p.buffers.len() {
            let want = fast.read_id(id);
            if sim.buffer(id) != want {
                return Err(fail(
                    Level::FastSim,
                    format!("buffer {id}, FastSim vs fused: {}", first_diff(sim.buffer(id), want)),
                ));
            }
            if slow.read_id(id) != want {
                return Err(fail(
                    Level::UnfusedPlan,
                    format!(
                        "buffer {id}, structural vs fused: {}",
                        first_diff(slow.read_id(id), want)
                    ),
                ));
            }
            if unfused.read_buffer(&st, id) != want {
                return Err(fail(
                    Level::UnfusedPlan,
                    format!(
                        "buffer {id}, standalone unfused vs fused: {}",
                        first_diff(unfused.read_buffer(&st, id), want)
                    ),
                ));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------- checker

    /// Static-checker differential (DESIGN.md §Static analysis).
    ///
    /// Planted-defect cases: the checker at [`CheckLevel::Strict`] must
    /// flag the planted diagnostic kind — a miss is a checker soundness
    /// bug (the defect provably exists by construction).
    ///
    /// Clean cases: the checker at [`CheckLevel::Standard`] (host
    /// envelope matching the generator's ±6000 bindings) must report
    /// zero diagnostics — a finding is a false positive — and the
    /// program must then agree across every raw-program fidelity level
    /// with every final lane value inside the checker's certified
    /// `[lo, hi]` range (interval soundness against real execution).
    pub fn run_check(&self, c: &CheckCase) -> Result<(), Divergence> {
        if let CheckDefect::Clean(pc) = &c.defect {
            let (p, binds) = pc.build();
            p.check()
                .map_err(|e| fail(Level::Check, format!("generated program invalid: {e}")))?;
            let opts = CheckOptions::new(CheckLevel::Standard)
                .with_device(self.device)
                .with_host_bound(6000);
            let report = check_program(&p, &opts);
            if !report.is_clean() {
                return Err(fail(
                    Level::Check,
                    format!("false positive on clean program: {}", report.diagnostics[0]),
                ));
            }
            // Cross-level agreement on the same case.
            self.run_program(pc)?;
            // Interval soundness: execute and compare against the
            // certified final ranges.
            let mut sim = FastSim::new(&p);
            for (id, data) in &binds {
                sim.set_buffer(*id, data);
            }
            for step in &p.steps {
                if let Step::Wave(w) = step {
                    sim.exec_wave(&p, w);
                }
            }
            for (b, ranges) in report.ranges.iter().enumerate() {
                for (i, (&v, r)) in sim.buffer(b).iter().zip(ranges).enumerate() {
                    if (v as i64) < r.0 || (v as i64) > r.1 {
                        return Err(fail(
                            Level::Check,
                            format!(
                                "interval unsound: buffer {b} lane {i} = {v} outside \
                                 certified [{}, {}]",
                                r.0, r.1
                            ),
                        ));
                    }
                }
            }
            return Ok(());
        }
        let (p, expect, cap) = c.build_planted();
        p.check()
            .map_err(|e| fail(Level::Check, format!("planted program invalid: {e}")))?;
        let mut opts = CheckOptions::new(CheckLevel::Strict).with_device(self.device);
        if let Some(cap) = cap {
            opts = opts.with_ring_capacity(cap);
        }
        let report = check_program(&p, &opts);
        if !report.diagnostics.iter().any(|d| d.kind() == expect) {
            return Err(fail(
                Level::Check,
                format!(
                    "planted `{expect}` NOT caught; checker reported: [{}]",
                    report
                        .diagnostics
                        .iter()
                        .map(|d| d.kind().to_string())
                        .collect::<Vec<_>>()
                        .join(", ")
                ),
            ));
        }
        Ok(())
    }

    // ------------------------------------------------------------ memplan

    /// Memory-planner differential: the same forward program executed
    /// with the static lane-reuse layout on vs off must produce
    /// bit-identical non-`Temp` buffers and identical
    /// [`crate::hw::RunStats`] — for both the fused and unfused plan
    /// variants — and the planned arena must never exceed the packed one
    /// (`Temp` lanes are excluded from the comparison because dead
    /// temporaries legitimately hold different values once their lanes
    /// are reused).
    pub fn run_memplan(&self, c: &MemplanCase) -> Result<(), Divergence> {
        let (lowered, binds) = match c {
            MemplanCase::Net(n) => {
                let spec = n.spec();
                let (qw, qb) = n.params();
                let lowered = lower_mlp_forward(&spec, n.batch)
                    .map_err(|e| fail(Level::MemPlan, format!("lowering failed: {e}")))?;
                let mut binds = vec![(lowered.x, n.input())];
                for l in 0..spec.layers.len() {
                    binds.push((lowered.weights[l], qw[l].clone()));
                    binds.push((lowered.biases[l], qb[l].clone()));
                }
                (lowered, binds)
            }
            MemplanCase::Graph(g) => {
                let spec = g.spec();
                let (qw, qb) = g.params();
                let decls = spec.param_decls().expect("generated graphs are valid");
                let lowered = lower_graph_forward(&spec, g.batch)
                    .map_err(|e| fail(Level::MemPlan, format!("graph lowering failed: {e}")))?;
                let mut binds = vec![(lowered.x, g.input())];
                for i in 0..decls.len() {
                    binds.push((lowered.weights[i], qw[i].clone()));
                    binds.push((lowered.biases[i], qb[i].clone()));
                }
                (lowered, binds)
            }
        };
        let program = &lowered.program;
        let mp = MemPlan::build(program);
        if mp.peak_lanes() > mp.packed_lanes() {
            return Err(fail(
                Level::MemPlan,
                format!(
                    "planned arena {} lanes exceeds the packed {} lanes",
                    mp.peak_lanes(),
                    mp.packed_lanes()
                ),
            ));
        }
        for (what, packed, planned) in [
            (
                "fused",
                ExecPlan::new(program, &self.device),
                ExecPlan::new_planned(program, &self.device),
            ),
            (
                "unfused",
                ExecPlan::new_unfused(program, &self.device),
                ExecPlan::new_unfused_planned(program, &self.device),
            ),
        ] {
            if planned.arena_len() > packed.arena_len() {
                return Err(fail(
                    Level::MemPlan,
                    format!(
                        "{what}: planned arena {} > packed arena {}",
                        planned.arena_len(),
                        packed.arena_len()
                    ),
                ));
            }
            let mut packed_st = packed.state();
            let mut planned_st = planned.state();
            for (id, data) in &binds {
                packed.write_buffer(&mut packed_st, *id, data);
                planned.write_buffer(&mut planned_st, *id, data);
            }
            let packed_stats = packed.execute(&mut packed_st);
            let planned_stats = planned.execute(&mut planned_st);
            if packed_stats != planned_stats {
                return Err(fail(
                    Level::MemPlan,
                    format!(
                        "{what}: cycle accounting, planned vs packed: \
                         {planned_stats:?} vs {packed_stats:?}"
                    ),
                ));
            }
            for (id, b) in program.buffers.iter().enumerate() {
                if b.kind == BufKind::Temp {
                    continue;
                }
                let mut want = packed.read_buffer(&packed_st, id).to_vec();
                if self.plant_divergence {
                    if let Some(v) = want.last_mut() {
                        *v ^= 1;
                    }
                }
                let got = planned.read_buffer(&planned_st, id);
                if got != want.as_slice() {
                    return Err(fail(
                        Level::MemPlan,
                        format!(
                            "{what}: buffer {id} ({:?}), planned vs packed: {}",
                            b.kind,
                            first_diff(got, &want)
                        ),
                    ));
                }
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------- faults

    /// Fault differential: under any generated [`FaultPlan`] the leader
    /// must terminate with either correct results (benign plans must be
    /// bit-identical to a clean run) or a typed [`ClusterError`] — and
    /// the outcome must be deterministic across replays.
    pub fn run_faults(&self, fc: &FaultCase) -> Result<(), Divergence> {
        let c = &fc.case;
        let jobs = self.jobs_for(c);
        let clean_cfg = self.cluster_config(c.boards, c.sync_every, c.sync, FaultPlan::none());
        let faulty_cfg = self.cluster_config(c.boards, c.sync_every, c.sync, fc.plan.clone());

        let clean = leader::execute(&clean_cfg, &jobs)
            .map_err(|e| fail(Level::Cluster, format!("clean run failed: {e}")))?;
        let f1 = leader::execute(&faulty_cfg, &jobs);
        let f2 = leader::execute(&faulty_cfg, &jobs);

        match (&f1, &f2) {
            (Ok(a), Ok(b)) => {
                for (x, y) in a.results.iter().zip(&b.results) {
                    if let Err(d) = job_results_equal(x, y) {
                        return Err(fail(
                            Level::Cluster,
                            format!("fault outcome nondeterministic for {:?}: {d}", x.name),
                        ));
                    }
                }
            }
            (Err(a), Err(b)) => {
                if a.to_string() != b.to_string() {
                    return Err(fail(
                        Level::Cluster,
                        format!("fault outcome nondeterministic: {a} vs {b}"),
                    ));
                }
            }
            _ => {
                return Err(fail(
                    Level::Cluster,
                    "fault outcome nondeterministic: Ok vs Err across replays".to_string(),
                ))
            }
        }

        match f1 {
            Ok(faulty) => {
                // A run that completes must match the clean run's
                // trained state exactly: delays are result-preserving by
                // design, and under the default RecoveryPolicy a lethal
                // fault either recovers **bit-identically** (chunks
                // rescheduled onto survivors, corrupt params re-read) or
                // aborts typed — so an Ok outcome with different
                // weights/curves is always a bug. Only the board
                // assignment may legitimately differ (rescheduling).
                //
                // The bit-exact bar applies to the deterministic sync
                // policies; a positive-lag `BoundedStale` run is only
                // held to the convergence oracle against the clean run.
                for (x, y) in clean.results.iter().zip(&faulty.results) {
                    let check = if c.sync.deterministic_vs_star() {
                        job_results_equivalent(x, y)
                    } else {
                        job_result_converged(x, y)
                    };
                    if let Err(d) = check {
                        return Err(fail(
                            Level::Cluster,
                            format!("faults changed a completed run's {:?}: {d}", x.name),
                        ));
                    }
                }
                Ok(())
            }
            Err(e) => {
                if fc.plan.is_benign() {
                    return Err(fail(
                        Level::Cluster,
                        format!("delay-only faults failed the run: {e}"),
                    ));
                }
                match e {
                    ClusterError::WorkerDied(..)
                    | ClusterError::CorruptChunk(..)
                    | ClusterError::Worker(..) => Ok(()),
                    other => Err(fail(
                        Level::Cluster,
                        format!("untyped/unexpected fault error: {other}"),
                    )),
                }
            }
        }
    }

    // ----------------------------------------------------------- recovery

    /// Recovery differential — the crash-tolerance acceptance property:
    /// a **survivable** fault plan (kills leave ≥ 1 board per recovery
    /// domain, corruptions within the retry budget) must *complete*
    /// under the default [`crate::cluster::RecoveryPolicy`] with
    /// weights, biases, loss curves, accuracy, and stats bit-identical
    /// to the fault-free run — and deterministically across replays.
    ///
    /// Under the deterministic sync policies (`Star`, `Ring`,
    /// `BoundedStale { max_lag: 0 }`) the recovered run is compared
    /// bit-for-bit against the fault-free one (eviction heals the ring
    /// without changing the averaging input). A positive-lag
    /// `BoundedStale` run keeps the completion and replay-determinism
    /// obligations but is held to the loss-descent convergence oracle
    /// instead of bit-exactness.
    pub fn run_recovery(&self, rc: &RecoveryCase) -> Result<(), Divergence> {
        let c = &rc.case;
        let jobs = self.jobs_for(c);
        let clean_cfg = self.cluster_config(c.boards, c.sync_every, c.sync, FaultPlan::none());
        let faulty_cfg = self.cluster_config(c.boards, c.sync_every, c.sync, rc.plan.clone());

        let clean = leader::execute(&clean_cfg, &jobs)
            .map_err(|e| fail(Level::Cluster, format!("clean run failed: {e}")))?;
        let f1 = leader::execute(&faulty_cfg, &jobs).map_err(|e| {
            fail(
                Level::Cluster,
                format!("survivable fault plan did not recover: {e}"),
            )
        })?;
        let f2 = leader::execute(&faulty_cfg, &jobs).map_err(|e| {
            fail(
                Level::Cluster,
                format!("survivable fault plan did not recover on replay: {e}"),
            )
        })?;
        // Replays agree on everything, including the (rescheduled)
        // board assignment.
        for (a, b) in f1.results.iter().zip(&f2.results) {
            if let Err(d) = job_results_equal(a, b) {
                return Err(fail(
                    Level::Cluster,
                    format!("recovered outcome nondeterministic for {:?}: {d}", a.name),
                ));
            }
        }
        // Bit-identical to fault-free, modulo board placement — or, for
        // positive-lag bounded staleness, still converged.
        for (x, y) in clean.results.iter().zip(&f1.results) {
            let check = if c.sync.deterministic_vs_star() {
                job_results_equivalent(x, y)
            } else {
                job_result_converged(x, y)
            };
            if let Err(d) = check {
                return Err(fail(
                    Level::Cluster,
                    format!("recovery diverged from the fault-free run's {:?}: {d}", x.name),
                ));
            }
        }
        Ok(())
    }
}

/// Bit-exact comparison of two job results (weights, biases, accuracy,
/// curve, stats, boards).
fn job_results_equal(a: &JobResult, b: &JobResult) -> Result<(), String> {
    if a.boards != b.boards {
        return Err(format!("boards {:?} vs {:?}", a.boards, b.boards));
    }
    job_results_equivalent(a, b)
}

/// Bit-exact comparison of the *trained state* of two job results —
/// everything except the board assignment, which recovery legitimately
/// changes when a job is rescheduled onto a surviving board.
fn job_results_equivalent(a: &JobResult, b: &JobResult) -> Result<(), String> {
    if a.weights != b.weights {
        return Err(format!("weights: {}", first_diff(&a.weights.concat(), &b.weights.concat())));
    }
    if a.biases != b.biases {
        return Err(format!("biases: {}", first_diff(&a.biases.concat(), &b.biases.concat())));
    }
    if a.accuracy != b.accuracy {
        return Err(format!("accuracy {} vs {}", a.accuracy, b.accuracy));
    }
    if a.curve != b.curve {
        return Err("loss curves differ".to_string());
    }
    if a.stats != b.stats {
        return Err(format!("stats {:?} vs {:?}", a.stats, b.stats));
    }
    Ok(())
}

/// Convergence oracle for sync policies without a bit-exact guarantee
/// (positive-lag [`SyncPolicy::BoundedStale`]): the run under test must
/// still *train* — a finite loss curve that does not rise materially
/// from its first recorded point and lands in the same neighbourhood as
/// the fault-free run — without matching the reference bit-for-bit.
/// Bounds are deliberately loose: the oracle is meant to catch blow-ups
/// (divergence, NaN-shaped wrap-around, a stale replica never
/// re-synced), not quantisation wobble on tiny generated nets.
fn job_result_converged(clean: &JobResult, got: &JobResult) -> Result<(), String> {
    let (Some(first), Some(last)) = (got.curve.first(), got.curve.last()) else {
        return Err("empty loss curve".to_string());
    };
    if !last.loss.is_finite() {
        return Err(format!("final loss {} is not finite", last.loss));
    }
    if last.loss > first.loss * 1.5 + 0.25 {
        return Err(format!(
            "loss rose from {:.4} to {:.4} under bounded staleness",
            first.loss, last.loss
        ));
    }
    let clean_last = clean.curve.last().map_or(f64::INFINITY, |p| p.loss);
    if last.loss > clean_last * 4.0 + 0.5 {
        return Err(format!(
            "final loss {:.4} far above the fault-free {clean_last:.4}",
            last.loss
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::gen;
    use crate::util::Rng;

    #[test]
    fn a_handful_of_net_cases_agree_across_levels() {
        let differ = Differ::default();
        let mut r = Rng::new(0x5EED);
        for i in 0..6 {
            let c = gen::net_case().sample(&mut r);
            differ.run_net(&c).unwrap_or_else(|d| panic!("case {i} ({c:?}): {d}"));
        }
    }

    #[test]
    fn a_handful_of_graph_cases_agree_across_levels() {
        let differ = Differ::default();
        let mut r = Rng::new(0x6AF5);
        for i in 0..6 {
            let c = gen::graph_case().sample(&mut r);
            differ.run_graph(&c).unwrap_or_else(|d| panic!("case {i} ({c:?}): {d}"));
        }
    }

    #[test]
    fn a_handful_of_program_cases_agree_across_levels() {
        let differ = Differ::default();
        let mut r = Rng::new(0xC0DE);
        for i in 0..6 {
            let c = gen::program_case().sample(&mut r);
            differ.run_program(&c).unwrap_or_else(|d| panic!("case {i} ({c:?}): {d}"));
        }
    }

    #[test]
    fn a_handful_of_memplan_cases_are_bit_exact_planned_vs_packed() {
        let differ = Differ::default();
        let mut r = Rng::new(0x3E37);
        for i in 0..6 {
            let c = gen::memplan_case().sample(&mut r);
            differ.run_memplan(&c).unwrap_or_else(|d| panic!("case {i} ({c:?}): {d}"));
        }
    }

    #[test]
    fn planted_divergence_is_detected_at_a_bit_exact_level() {
        let differ = Differ::default().with_plant(true);
        let c = gen::net_case().sample(&mut Rng::new(1));
        let d = differ.run_net(&c).expect_err("plant must diverge");
        assert_eq!(d.level, Level::FusedPlan, "{d}");
    }

    #[test]
    fn one_train_case_agrees_across_engines() {
        let differ = Differ::default();
        let c = gen::fuzz_case().sample(&mut Rng::new(0xAB));
        differ.run_train(&c).unwrap_or_else(|d| panic!("{c:?}: {d}"));
    }

    #[test]
    fn a_handful_of_recovery_cases_complete_bit_identically() {
        let differ = Differ::default();
        let mut r = Rng::new(0x4EC);
        for i in 0..3 {
            let c = gen::recovery_case().sample(&mut r);
            differ.run_recovery(&c).unwrap_or_else(|d| panic!("case {i} ({c:?}): {d}"));
        }
    }

    #[test]
    fn a_handful_of_serve_cases_are_bit_exact_vs_sequential_infer() {
        let differ = Differ::default();
        let mut r = Rng::new(0x5E57E);
        for i in 0..4 {
            let c = gen::fuzz_case().sample(&mut r);
            differ.run_serve(&c).unwrap_or_else(|d| panic!("case {i} ({c:?}): {d}"));
        }
    }

    #[test]
    fn a_handful_of_serve_chaos_cases_terminate_and_match_the_reference() {
        let differ = Differ::default();
        let mut r = Rng::new(0xC4A05);
        for i in 0..3 {
            let c = gen::serve_chaos_case().sample(&mut r);
            differ.run_serve_chaos(&c).unwrap_or_else(|d| panic!("case {i} ({c:?}): {d}"));
        }
    }
}
