//! The fuzz harness: generate cases, run the differential executor,
//! shrink failures to minimal cases, and replay them from printed seeds.
//!
//! Seed discipline: case `i` of a run with base seed `S` executes at
//! `case_seed(S, i)`, and `case_seed(S, 0) == S` — so the seed a failure
//! prints reproduces that exact case via `mfnn fuzz --cases 1 --seed N`.
//! Corpus snapshot files (`rust/tests/corpus/*.seeds`) store
//! `family seed` lines in the same format the failure file uses, so a
//! CI-uploaded failure file can be replayed directly with
//! `mfnn fuzz --corpus <file>`.

use super::diff::{Differ, Divergence};
use super::gen;
use crate::cluster::cost::SyncPolicy;
use crate::hw::FpgaDevice;
use crate::prop::Gen;
use crate::util::Rng;
use std::fmt::Debug;
use std::fmt::Write as _;

/// Per-case seed stride (odd, so consecutive cases decorrelate; index 0
/// maps to the base seed itself for exact replay).
const SEED_STRIDE: u64 = 0x9E3779B97F4A7C15;

/// Derive the seed of case `index` from the run's base seed.
/// `case_seed(base, 0) == base`.
pub fn case_seed(base: u64, index: usize) -> u64 {
    base.wrapping_add((index as u64).wrapping_mul(SEED_STRIDE))
}

/// The eight generated case families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// [`gen::FuzzCase`]: forward + training + cluster levels.
    Net,
    /// [`gen::GraphCase`]: operator-graph nets (residual / gated / CNN /
    /// transformer-block) through the forward fidelity levels.
    Graph,
    /// [`gen::ProgramCase`]: raw-program levels.
    Program,
    /// [`gen::FaultCase`]: cluster fault injection (never hang: finish
    /// bit-identically — recovered or benign — or abort typed).
    Fault,
    /// [`gen::RecoveryCase`]: survivable fault plans (kills leave ≥ 1
    /// board per recovery domain) must complete bit-identically to the
    /// fault-free run under the default recovery policy.
    Recovery,
    /// [`gen::ServeChaosCase`]: survivable serving fault plans — every
    /// admitted request terminates typed, completed outputs stay
    /// bit-identical to the batch-1 reference, outcome replays
    /// deterministically.
    ServeChaos,
    /// [`gen::MemplanCase`]: the static memory planner on vs off must
    /// be behaviour-invisible — bit-identical outputs, identical
    /// `RunStats`, planned arena never larger than the packed one.
    Memplan,
    /// [`gen::CheckCase`]: the static checker must catch every planted
    /// defect and pass clean programs, whose execution must then stay
    /// inside the certified value ranges.
    Check,
}

impl Family {
    /// All families, in execution order.
    pub const ALL: [Family; 8] = [
        Family::Net,
        Family::Graph,
        Family::Program,
        Family::Fault,
        Family::Recovery,
        Family::ServeChaos,
        Family::Memplan,
        Family::Check,
    ];

    /// Stable name used in corpus/failure files.
    pub fn name(&self) -> &'static str {
        match self {
            Family::Net => "net",
            Family::Graph => "graph",
            Family::Program => "program",
            Family::Fault => "fault",
            Family::Recovery => "recovery",
            Family::ServeChaos => "serve-chaos",
            Family::Memplan => "memplan",
            Family::Check => "check",
        }
    }

    /// Parse a corpus family tag.
    pub fn parse(s: &str) -> Option<Family> {
        match s {
            "net" => Some(Family::Net),
            "graph" => Some(Family::Graph),
            "program" => Some(Family::Program),
            "fault" => Some(Family::Fault),
            "recovery" => Some(Family::Recovery),
            "serve-chaos" => Some(Family::ServeChaos),
            "memplan" => Some(Family::Memplan),
            "check" => Some(Family::Check),
            _ => None,
        }
    }
}

impl std::fmt::Display for Family {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Fuzz-run options.
#[derive(Debug, Clone)]
pub struct FuzzOptions {
    /// Generated cases per family.
    pub cases: usize,
    /// Base seed (case `i` runs at [`case_seed`]`(seed, i)`).
    pub seed: u64,
    /// Board part every level simulates.
    pub device: FpgaDevice,
    /// Test-only hook: plant a known FastSim divergence (must be caught).
    pub plant_divergence: bool,
    /// Shrink-step budget per failure.
    pub max_shrink_steps: usize,
    /// Re-run each failure's seed to confirm it reproduces.
    pub check_reproduction: bool,
    /// Restrict the run to one family (`None` = all eight) —
    /// `mfnn fuzz --family recovery`, `--family serve-chaos`, and
    /// `--family memplan` are the CI recovery, chaos, and
    /// memory-planner smokes.
    pub family: Option<Family>,
    /// Force every cluster-bearing case to one [`SyncPolicy`],
    /// overriding the generator's sampled `FuzzCase::sync` —
    /// `mfnn fuzz --family recovery --sync ring` is the CI ring-healing
    /// smoke. A failure found under an override replays only with the
    /// same `--sync` flag.
    pub sync_override: Option<SyncPolicy>,
}

impl Default for FuzzOptions {
    fn default() -> FuzzOptions {
        FuzzOptions {
            cases: 64,
            seed: 0,
            device: FpgaDevice::selected(),
            plant_divergence: false,
            max_shrink_steps: 100,
            check_reproduction: true,
            family: None,
            sync_override: None,
        }
    }
}

/// One caught, shrunk divergence.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    /// Case family.
    pub family: Family,
    /// Case index within the run.
    pub case_index: usize,
    /// The seed that reproduces the case exactly.
    pub seed: u64,
    /// Divergence of the *shrunk* case.
    pub divergence: String,
    /// Debug rendering of the original generated case.
    pub original: String,
    /// Debug rendering of the minimal shrunk case.
    pub shrunk: String,
    /// Shrink steps applied.
    pub shrink_steps: usize,
    /// Whether re-running the printed seed reproduced a divergence.
    pub reproduced: bool,
}

/// Result of a fuzz run.
#[derive(Debug, Clone, Default)]
pub struct FuzzReport {
    /// Generated cases per family (fuzz runs) or total corpus entries
    /// replayed (corpus runs — see [`FuzzReport::corpus`]).
    pub cases: usize,
    /// Families executed (distinct families for corpus runs).
    pub families: usize,
    /// True for corpus replays, where each entry runs exactly one
    /// family (so `cases` is the total run count, not per-family).
    pub corpus: bool,
    /// Caught divergences.
    pub failures: Vec<FuzzFailure>,
}

impl FuzzReport {
    /// True when every case agreed at every level.
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    /// Human-readable summary (what `mfnn fuzz` prints).
    pub fn render(&self) -> String {
        let mut s = if self.corpus {
            format!(
                "fuzz: replayed {} corpus entries spanning {} families — {} divergence(s)\n",
                self.cases,
                self.families,
                self.failures.len()
            )
        } else {
            format!(
                "fuzz: {} case(s) × {} families — {} divergence(s)\n",
                self.cases,
                self.families,
                self.failures.len()
            )
        };
        for f in &self.failures {
            let _ = writeln!(
                s,
                "FAIL [{}] case {} seed {}: {}\n  original: {}\n  shrunk ({} step(s)): {}\n  \
                 reproduce: mfnn fuzz --cases 1 --seed {}\n  reproduced from seed: {}",
                f.family,
                f.case_index,
                f.seed,
                f.divergence,
                f.original,
                f.shrink_steps,
                f.shrunk,
                f.seed,
                if f.reproduced { "yes" } else { "NO" },
            );
        }
        s
    }

    /// Failure-file body: `family seed  # divergence` lines, replayable
    /// with `mfnn fuzz --corpus <file>`.
    pub fn failures_file(&self) -> String {
        let mut s =
            String::from("# failing fuzz seeds — replay with `mfnn fuzz --corpus <file>`\n");
        for f in &self.failures {
            let _ = writeln!(s, "{} {}  # {}", f.family, f.seed, f.divergence);
        }
        s
    }
}

/// The Net family's full differential sequence — the single definition
/// shared by [`run_case`] and the fuzz loop, so the public replay entry
/// point can never drift out of sync with what the fuzzer checks.
fn run_net_family(differ: &Differ, c: &gen::FuzzCase) -> Result<(), Divergence> {
    differ.run_net(&c.net)?;
    differ.run_serve(c)?;
    differ.run_train(c)?;
    differ.run_cluster(c)
}

/// Apply a [`FuzzOptions::sync_override`] to a sampled case's cluster
/// phase (identity when no override is set).
fn with_sync(c: &gen::FuzzCase, sync: Option<SyncPolicy>) -> gen::FuzzCase {
    match sync {
        Some(s) => gen::FuzzCase { sync: s, ..c.clone() },
        None => c.clone(),
    }
}

/// Run one family's case at `seed` through its differential levels.
pub fn run_case(differ: &Differ, family: Family, seed: u64) -> Result<(), Divergence> {
    let mut rng = Rng::new(seed);
    match family {
        Family::Net => run_net_family(differ, &gen::fuzz_case().sample(&mut rng)),
        Family::Graph => differ.run_graph(&gen::graph_case().sample(&mut rng)),
        Family::Program => differ.run_program(&gen::program_case().sample(&mut rng)),
        Family::Fault => differ.run_faults(&gen::fault_case().sample(&mut rng)),
        Family::Recovery => differ.run_recovery(&gen::recovery_case().sample(&mut rng)),
        Family::ServeChaos => differ.run_serve_chaos(&gen::serve_chaos_case().sample(&mut rng)),
        Family::Memplan => differ.run_memplan(&gen::memplan_case().sample(&mut rng)),
        Family::Check => differ.run_check(&gen::check_case().sample(&mut rng)),
    }
}

/// Greedy shrink: repeatedly take the first shrink candidate that still
/// diverges, up to `max_steps`.
fn shrink_failure<T: Clone + Debug>(
    g: &Gen<T>,
    mut best: T,
    first: Divergence,
    run: impl Fn(&T) -> Result<(), Divergence>,
    max_steps: usize,
) -> (T, Divergence, usize) {
    let mut last = first;
    let mut steps = 0usize;
    'outer: loop {
        for cand in g.shrink(&best) {
            if let Err(d) = run(&cand) {
                best = cand;
                last = d;
                steps += 1;
                if steps >= max_steps {
                    break 'outer;
                }
                continue 'outer;
            }
        }
        break;
    }
    (best, last, steps)
}

/// Run one family's generator at `seed`; on divergence, shrink greedily
/// and return the recorded failure.
fn fuzz_family<T: Clone + Debug>(
    opts: &FuzzOptions,
    family: Family,
    case_index: usize,
    seed: u64,
    g: &Gen<T>,
    run: impl Fn(&T) -> Result<(), Divergence>,
) -> Option<FuzzFailure> {
    let c = g.sample(&mut Rng::new(seed));
    let original = format!("{c:?}");
    let first = match run(&c) {
        Ok(()) => return None,
        Err(d) => d,
    };
    let (shrunk, divergence, shrink_steps) =
        shrink_failure(g, c, first, &run, opts.max_shrink_steps);
    // Self-check the replay story: resampling the printed seed must
    // reproduce a divergence.
    let reproduced =
        opts.check_reproduction && run(&g.sample(&mut Rng::new(seed))).is_err();
    Some(FuzzFailure {
        family,
        case_index,
        seed,
        divergence: divergence.to_string(),
        original,
        shrunk: format!("{shrunk:?}"),
        shrink_steps,
        reproduced,
    })
}

/// Run one family at `seed`; on divergence, shrink and record a failure.
fn fuzz_one(
    differ: &Differ,
    opts: &FuzzOptions,
    family: Family,
    case_index: usize,
    seed: u64,
    failures: &mut Vec<FuzzFailure>,
) {
    let failure = match family {
        Family::Net => fuzz_family(opts, family, case_index, seed, &gen::fuzz_case(), |c| {
            run_net_family(differ, &with_sync(c, opts.sync_override))
        }),
        Family::Graph => fuzz_family(opts, family, case_index, seed, &gen::graph_case(), |c| {
            differ.run_graph(c)
        }),
        Family::Program => fuzz_family(opts, family, case_index, seed, &gen::program_case(), |c| {
            differ.run_program(c)
        }),
        Family::Fault => fuzz_family(opts, family, case_index, seed, &gen::fault_case(), |c| {
            differ.run_faults(&gen::FaultCase {
                case: with_sync(&c.case, opts.sync_override),
                plan: c.plan.clone(),
            })
        }),
        Family::Recovery => {
            fuzz_family(opts, family, case_index, seed, &gen::recovery_case(), |c| {
                differ.run_recovery(&gen::RecoveryCase {
                    case: with_sync(&c.case, opts.sync_override),
                    plan: c.plan.clone(),
                })
            })
        }
        Family::ServeChaos => {
            fuzz_family(opts, family, case_index, seed, &gen::serve_chaos_case(), |c| {
                differ.run_serve_chaos(c)
            })
        }
        Family::Memplan => {
            fuzz_family(opts, family, case_index, seed, &gen::memplan_case(), |c| {
                differ.run_memplan(c)
            })
        }
        Family::Check => {
            fuzz_family(opts, family, case_index, seed, &gen::check_case(), |c| {
                differ.run_check(c)
            })
        }
    };
    failures.extend(failure);
}

/// Run the full differential fuzz: `opts.cases` cases per family, every
/// case through every applicable fidelity level.
pub fn fuzz(opts: &FuzzOptions) -> FuzzReport {
    let differ = Differ::new(opts.device).with_plant(opts.plant_divergence);
    let families: Vec<Family> = Family::ALL
        .into_iter()
        .filter(|f| opts.family.is_none_or(|only| only == *f))
        .collect();
    let mut report = FuzzReport {
        cases: opts.cases,
        families: families.len(),
        corpus: false,
        failures: Vec::new(),
    };
    for i in 0..opts.cases {
        let seed = case_seed(opts.seed, i);
        for &family in &families {
            fuzz_one(&differ, opts, family, i, seed, &mut report.failures);
        }
    }
    report
}

/// Parse a corpus snapshot: `family seed` per line, `#` comments and
/// blank lines ignored.
pub fn parse_corpus(text: &str) -> Result<Vec<(Family, u64)>, String> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let fam = parts
            .next()
            .and_then(Family::parse)
            .ok_or_else(|| {
                format!(
                    "line {}: expected \
                     `net|graph|program|fault|recovery|serve-chaos|memplan|check <seed>`",
                    ln + 1
                )
            })?;
        let seed: u64 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format!("line {}: bad seed", ln + 1))?;
        if let Some(extra) = parts.next() {
            // Reject rather than silently dropping a regression seed
            // (e.g. two lines accidentally merged when appending).
            return Err(format!(
                "line {}: unexpected trailing token {extra:?} after the seed",
                ln + 1
            ));
        }
        out.push((fam, seed));
    }
    Ok(out)
}

/// Replay corpus entries (regression seeds / CI failure files) through
/// the differential executor. Each entry runs exactly one family, so
/// the report counts the distinct families actually present.
pub fn replay_corpus(entries: &[(Family, u64)], opts: &FuzzOptions) -> FuzzReport {
    let differ = Differ::new(opts.device).with_plant(opts.plant_divergence);
    let mut report = FuzzReport {
        cases: entries.len(),
        families: Family::ALL
            .iter()
            .filter(|f| entries.iter().any(|(ef, _)| ef == *f))
            .count(),
        corpus: true,
        failures: Vec::new(),
    };
    for (i, &(family, seed)) in entries.iter().enumerate() {
        fuzz_one(&differ, opts, family, i, seed, &mut report.failures);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_seed_is_replayable_and_decorrelated() {
        assert_eq!(case_seed(42, 0), 42);
        assert_ne!(case_seed(42, 1), case_seed(42, 2));
        // the seed printed for case i IS a base seed whose case 0 is it
        let s = case_seed(7, 3);
        assert_eq!(case_seed(s, 0), s);
    }

    #[test]
    fn corpus_parses_tags_seeds_and_comments() {
        let text = "# comment\n\nnet 12  # trailing\nprogram 0\nfault 99\nrecovery 7\n\
                    serve-chaos 3\ngraph 5\nmemplan 8\ncheck 4\n";
        let entries = parse_corpus(text).unwrap();
        assert_eq!(
            entries,
            vec![
                (Family::Net, 12),
                (Family::Program, 0),
                (Family::Fault, 99),
                (Family::Recovery, 7),
                (Family::ServeChaos, 3),
                (Family::Graph, 5),
                (Family::Memplan, 8),
                (Family::Check, 4)
            ]
        );
        assert!(parse_corpus("bogus 1").is_err());
        assert!(parse_corpus("net notanumber").is_err());
        // merged lines must be rejected, not silently truncated
        assert!(parse_corpus("net 12 34").is_err());
    }

    #[test]
    fn family_filter_restricts_the_run() {
        // A filtered run executes exactly one family (cases = 0 keeps
        // this a pure bookkeeping test — no differential work).
        let opts = FuzzOptions {
            cases: 0,
            family: Some(Family::Recovery),
            ..FuzzOptions::default()
        };
        let report = fuzz(&opts);
        assert_eq!(report.families, 1);
        assert!(report.ok());
        let all = fuzz(&FuzzOptions { cases: 0, ..FuzzOptions::default() });
        assert_eq!(all.families, Family::ALL.len());
    }

    #[test]
    fn sync_override_rewrites_only_the_policy() {
        let c = gen::fuzz_case().sample(&mut Rng::new(9));
        let forced = with_sync(&c, Some(SyncPolicy::Ring));
        assert_eq!(forced.sync, SyncPolicy::Ring);
        assert_eq!(with_sync(&c, None), c);
        assert_eq!(gen::FuzzCase { sync: c.sync, ..forced }, c);
    }

    #[test]
    fn failure_file_round_trips_through_the_corpus_parser() {
        let report = FuzzReport {
            cases: 1,
            families: 3,
            corpus: false,
            failures: vec![FuzzFailure {
                family: Family::Net,
                case_index: 0,
                seed: 1234,
                divergence: "[fused_plan] demo".into(),
                original: "X".into(),
                shrunk: "Y".into(),
                shrink_steps: 2,
                reproduced: true,
            }],
        };
        let entries = parse_corpus(&report.failures_file()).unwrap();
        assert_eq!(entries, vec![(Family::Net, 1234)]);
        assert!(report.render().contains("--seed 1234"));
    }
}
