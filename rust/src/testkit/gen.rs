//! Seeded case generators for the differential fuzzer, built on the
//! [`crate::prop::Gen`] mini-framework.
//!
//! Three case families, each `Debug + Clone` and regenerated *exactly*
//! from a single `u64` seed (the number a failure prints):
//!
//! * [`FuzzCase`] — a random [`MlpSpec`] with derived parameters, inputs,
//!   dataset, training shape, and an M×F cluster topology sweeping all
//!   three §2 placements. Drives the net/train/cluster differential
//!   levels.
//! * [`ProgramCase`] — a random raw vector [`Program`] over the six
//!   executable opcodes (`Nop` has no lane semantics and no microcode
//!   lowering, so it is intentionally excluded) with its input
//!   bindings. Drives the raw-program levels (FastSim vs unfused vs
//!   fused vs structural).
//! * [`FaultCase`] — a topology plus a deterministic
//!   [`FaultPlan`] for the cluster fault differential.
//! * [`ServeChaosCase`] — a topology plus a survivable
//!   [`ServeFaultPlan`] for the serving degraded-mode differential.
//! * [`GraphCase`] — a random well-typed operator graph
//!   ([`GraphSpec`]: residual, gated, CNN, or transformer-block shaped)
//!   with derived parameters and input. Drives the graph forward
//!   differential levels.
//! * [`MemplanCase`] — a [`NetCase`] or [`GraphCase`] run with the
//!   static memory planner on vs off: outputs and `RunStats` must be
//!   bit-identical and the planned arena never larger.
//! * [`CheckCase`] — a program with one planted defect the static
//!   checker must flag, or a clean [`ProgramCase`] it must pass and
//!   whose execution must stay inside the certified value ranges.
//!
//! Every generator pairs a structured shrinker so a divergence shrinks
//! toward the minimal failing case (fewer layers, dim 1, batch 1, one
//! board, one wave) — the [`crate::testkit::fuzz`] harness drives the
//! shrink loop.

use crate::assembler::program::{BufId, BufKind, LaneOp, Program, Step, View, Wave};
use crate::cluster::cost::SyncPolicy;
use crate::cluster::fault::FaultPlan;
use crate::cluster::scheduler::{schedule, PlacementMode};
use crate::fixed::FixedSpec;
use crate::isa::Opcode;
use crate::nn::graph::{Conv2dGeom, GraphSpec, INPUT};
use crate::nn::lut::{ActKind, ActLut, AddrMode};
use crate::nn::mlp::{LutParams, MlpSpec};
use crate::nn::trainer::TrainConfig;
use crate::nn::{dataset, dataset::Dataset};
use crate::prop::Gen;
use crate::serve::ServeFaultPlan;
use crate::util::Rng;

/// Salt for deriving per-case parameter streams from the case seed.
const SALT_PARAMS: u64 = 0x9E3779B97F4A7C15;
/// Salt for the input/target batch stream.
const SALT_IO: u64 = 0xD1B54A32D192ED03;
/// Salt for the dataset stream.
const SALT_DATA: u64 = 0x94D049BB133111EB;

// ---------------------------------------------------------------- networks

/// One generated network with derived bindings: everything the forward
/// differential levels need, compact enough to shrink structurally.
/// Parameters, inputs, and targets are re-derived from `seed` + the
/// current shapes, so shrinking `dims` keeps the case self-consistent.
#[derive(Debug, Clone, PartialEq)]
pub struct NetCase {
    /// Case seed (printed on failure; regenerates the case exactly).
    pub seed: u64,
    /// Dimension list `[in, h1, ..., out]` (layers are `dims.windows(2)`).
    pub dims: Vec<usize>,
    /// Hidden activation.
    pub act: ActKind,
    /// Output activation.
    pub out_act: ActKind,
    /// Fractional bits of the (saturating) datapath.
    pub frac_bits: u32,
    /// Batch rows.
    pub batch: usize,
}

impl NetCase {
    /// The saturating fixed-point format of the case.
    pub fn fixed(&self) -> FixedSpec {
        FixedSpec::q(self.frac_bits).saturating()
    }

    /// The validated spec (generated dims are always valid).
    pub fn spec(&self) -> MlpSpec {
        let fixed = self.fixed();
        MlpSpec::from_dims(
            "fuzz",
            &self.dims,
            self.act,
            self.out_act,
            fixed,
            LutParams::training(fixed),
        )
        .expect("generated dims are valid")
    }

    /// Deterministic quantised parameters: `|w| ≤ 1/fan_in`, `|b| ≤ 0.25`
    /// — keeps every activation far from the Q range so the float oracle
    /// stays comparable (no saturation events on the forward pass).
    pub fn params(&self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        let fixed = self.fixed();
        let mut r = Rng::new(self.seed ^ SALT_PARAMS);
        let spec = self.spec();
        let mut w = Vec::with_capacity(spec.layers.len());
        let mut b = Vec::with_capacity(spec.layers.len());
        for layer in &spec.layers {
            let scale = 1.0 / layer.inputs as f64;
            w.push(
                (0..layer.inputs * layer.outputs)
                    .map(|_| fixed.from_f64((r.gen_f64() * 2.0 - 1.0) * scale))
                    .collect(),
            );
            b.push(
                (0..layer.outputs)
                    .map(|_| fixed.from_f64((r.gen_f64() * 2.0 - 1.0) * 0.25))
                    .collect(),
            );
        }
        (w, b)
    }

    /// Deterministic quantised `batch × in_dim` input in `[-1, 1]`.
    pub fn input(&self) -> Vec<i16> {
        let fixed = self.fixed();
        let mut r = Rng::new(self.seed ^ SALT_IO);
        (0..self.batch * self.dims[0])
            .map(|_| fixed.from_f64(r.gen_f64() * 2.0 - 1.0))
            .collect()
    }

    /// Deterministic quantised `batch × out_dim` target batch in
    /// `[-1, 1]` (for single-train-step differentials).
    pub fn targets(&self) -> Vec<i16> {
        let fixed = self.fixed();
        let mut r = Rng::new(self.seed ^ SALT_IO ^ SALT_DATA);
        (0..self.batch * self.dims[self.dims.len() - 1])
            .map(|_| fixed.from_f64(r.gen_f64() * 2.0 - 1.0))
            .collect()
    }
}

fn sample_net_case(r: &mut Rng) -> NetCase {
    let n_layers = 1 + r.gen_range(3) as usize; // 1..=3
    let dims: Vec<usize> =
        (0..=n_layers).map(|_| 1 + r.gen_range(8) as usize).collect(); // 1..=8 each
    NetCase {
        seed: r.next_u64(),
        dims,
        act: *r.choose(&[ActKind::Relu, ActKind::Sigmoid, ActKind::Tanh, ActKind::Identity]),
        out_act: *r.choose(&[ActKind::Identity, ActKind::Sigmoid, ActKind::Tanh]),
        frac_bits: 8 + r.gen_range(4) as u32, // Q8..Q11
        batch: 1 + r.gen_range(8) as usize,   // 1..=8
    }
}

fn shrink_net_case(c: &NetCase) -> Vec<NetCase> {
    let mut out = Vec::new();
    // fewer layers: drop an interior dim (adjacent pairs stay valid)
    if c.dims.len() > 2 {
        for i in 1..c.dims.len() - 1 {
            let mut d = c.clone();
            d.dims.remove(i);
            out.push(d);
        }
    }
    // smaller dims, toward 1
    for i in 0..c.dims.len() {
        if c.dims[i] > 1 {
            let mut d = c.clone();
            d.dims[i] = c.dims[i] / 2;
            out.push(d);
        }
    }
    // smaller batch
    if c.batch > 1 {
        let mut d = c.clone();
        d.batch = c.batch / 2;
        out.push(d);
    }
    // simpler activations
    if c.act != ActKind::Relu {
        let mut d = c.clone();
        d.act = ActKind::Relu;
        out.push(d);
    }
    if c.out_act != ActKind::Identity {
        let mut d = c.clone();
        d.out_act = ActKind::Identity;
        out.push(d);
    }
    out
}

/// Generator for [`NetCase`].
pub fn net_case() -> Gen<NetCase> {
    Gen::new(sample_net_case, shrink_net_case)
}

// ------------------------------------------------------- operator graphs

/// Architecture family of a generated [`GraphCase`] — four shapes that
/// together exercise every [`crate::nn::graph::OpKind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphArch {
    /// `linear(hidden) → act → linear(dim) → add(input) → norm(dim)` —
    /// the minimal residual block (Linear, Activation, ElemAdd,
    /// Normalization).
    Residual,
    /// `mul(act(linear(hidden)), linear(hidden)) → linear(dim)` — a
    /// gated unit (ElemMul plus a diamond-shaped dataflow).
    Gated,
    /// `conv2d(2×2, out_c=hidden) → act → linear(dim)` — a one-layer
    /// CNN classifier head (Conv2d via im2col).
    Cnn,
    /// `attention(seq=dim, d=hidden) → add → norm(d) → linear → act →
    /// linear → add → norm(d)` — a full pre-MLP transformer block
    /// (Attention plus both residual/norm sites).
    TransformerBlock,
}

/// One generated operator-graph net with derived bindings, the graph
/// twin of [`NetCase`]: parameters and input are re-derived from `seed`
/// + the current sizes, so shrinking keeps the case self-consistent.
/// Sizes are kept small (≤ 5) and the datapath at Q8–Q9 so attention's
/// un-shifted `Exp` scores stay representable.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphCase {
    /// Case seed (printed on failure; regenerates the case exactly).
    pub seed: u64,
    /// Architecture family.
    pub arch: GraphArch,
    /// Primary size: residual/gated width, CNN output side, tokens.
    pub dim: usize,
    /// Secondary size: bottleneck width, conv channels, head width.
    pub hidden: usize,
    /// Activation used at every activation site.
    pub act: ActKind,
    /// Fractional bits of the (saturating) datapath.
    pub frac_bits: u32,
    /// Batch rows.
    pub batch: usize,
}

impl GraphCase {
    /// The saturating fixed-point format of the case.
    pub fn fixed(&self) -> FixedSpec {
        FixedSpec::q(self.frac_bits).saturating()
    }

    /// The validated graph (generated sizes are always valid).
    pub fn spec(&self) -> GraphSpec {
        let fixed = self.fixed();
        let lut = LutParams::training(fixed);
        match self.arch {
            GraphArch::Residual => {
                let mut g = GraphSpec::new("fuzz_graph", self.dim, fixed, lut);
                let l1 = g.linear(INPUT, self.hidden);
                let a1 = g.activation(l1, self.act);
                let l2 = g.linear(a1, self.dim);
                let res = g.add(l2, INPUT);
                g.normalization(res, self.dim);
                g
            }
            GraphArch::Gated => {
                let mut g = GraphSpec::new("fuzz_graph", self.dim, fixed, lut);
                let gate = g.linear(INPUT, self.hidden);
                let ga = g.activation(gate, self.act);
                let val = g.linear(INPUT, self.hidden);
                let m = g.mul(ga, val);
                g.linear(m, self.dim);
                g
            }
            GraphArch::Cnn => {
                let side = self.dim + 1; // a 2×2 kernel always fits
                let geom = Conv2dGeom {
                    in_h: side,
                    in_w: side,
                    in_c: 1,
                    out_c: self.hidden,
                    kh: 2,
                    kw: 2,
                    stride: 1,
                };
                let mut g = GraphSpec::new("fuzz_graph", geom.in_dim(), fixed, lut);
                let c = g.conv2d(INPUT, geom);
                let a = g.activation(c, self.act);
                g.linear(a, self.dim);
                g
            }
            GraphArch::TransformerBlock => {
                let (seq, d) = (self.dim, self.hidden);
                let width = seq * d;
                let mut g = GraphSpec::new("fuzz_graph", width, fixed, lut);
                let att = g.attention(INPUT, seq, d);
                let r1 = g.add(att, INPUT);
                let n1 = g.normalization(r1, d);
                let f1 = g.linear(n1, width);
                let fa = g.activation(f1, self.act);
                let f2 = g.linear(fa, width);
                let r2 = g.add(f2, n1);
                g.normalization(r2, d);
                g
            }
        }
    }

    /// Deterministic quantised parameters in
    /// [`GraphSpec::param_decls`] order: `|w| ≤ 1/fan_in`, `|b| ≤ 0.25`
    /// — same comparability recipe as [`NetCase::params`].
    pub fn params(&self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        let fixed = self.fixed();
        let mut r = Rng::new(self.seed ^ SALT_PARAMS);
        let decls = self.spec().param_decls().expect("generated graphs are valid");
        let mut w = Vec::with_capacity(decls.len());
        let mut b = Vec::with_capacity(decls.len());
        for d in &decls {
            let scale = 1.0 / d.rows as f64;
            w.push(
                (0..d.rows * d.cols)
                    .map(|_| fixed.from_f64((r.gen_f64() * 2.0 - 1.0) * scale))
                    .collect(),
            );
            b.push(
                (0..d.cols)
                    .map(|_| fixed.from_f64((r.gen_f64() * 2.0 - 1.0) * 0.25))
                    .collect(),
            );
        }
        (w, b)
    }

    /// Deterministic quantised `batch × in_dim` input in `[-1, 1]`.
    pub fn input(&self) -> Vec<i16> {
        let fixed = self.fixed();
        let mut r = Rng::new(self.seed ^ SALT_IO);
        (0..self.batch * self.spec().input_dim())
            .map(|_| fixed.from_f64(r.gen_f64() * 2.0 - 1.0))
            .collect()
    }
}

pub(crate) fn sample_graph_case(r: &mut Rng) -> GraphCase {
    GraphCase {
        seed: r.next_u64(),
        arch: *r.choose(&[
            GraphArch::Residual,
            GraphArch::Gated,
            GraphArch::Cnn,
            GraphArch::TransformerBlock,
        ]),
        dim: 1 + r.gen_range(5) as usize,    // 1..=5
        hidden: 1 + r.gen_range(4) as usize, // 1..=4
        act: *r.choose(&[ActKind::Relu, ActKind::Sigmoid, ActKind::Tanh, ActKind::Identity]),
        frac_bits: 8 + r.gen_range(2) as u32, // Q8..Q9
        batch: 1 + r.gen_range(4) as usize,   // 1..=4
    }
}

fn shrink_graph_case(c: &GraphCase) -> Vec<GraphCase> {
    let mut out = Vec::new();
    // simplest architecture first (fewest ops, no LUT-heavy blocks)
    if c.arch != GraphArch::Residual {
        out.push(GraphCase { arch: GraphArch::Residual, ..c.clone() });
    }
    if c.dim > 1 {
        out.push(GraphCase { dim: c.dim / 2, ..c.clone() });
    }
    if c.hidden > 1 {
        out.push(GraphCase { hidden: c.hidden / 2, ..c.clone() });
    }
    if c.batch > 1 {
        out.push(GraphCase { batch: c.batch / 2, ..c.clone() });
    }
    if c.act != ActKind::Relu {
        out.push(GraphCase { act: ActKind::Relu, ..c.clone() });
    }
    out
}

/// Generator for [`GraphCase`].
pub fn graph_case() -> Gen<GraphCase> {
    Gen::new(sample_graph_case, shrink_graph_case)
}

// -------------------------------------------------------- full fuzz cases

/// One full differential-fuzz case: a net, a training-run shape, and an
/// M×F cluster topology. All five fidelity levels derive from this.
#[derive(Debug, Clone, PartialEq)]
pub struct FuzzCase {
    /// The network (forward levels; also the net every cluster job trains).
    pub net: NetCase,
    /// SGD steps per job.
    pub steps: usize,
    /// `lr = 2^-lr_pow` — always exactly representable in the datapath.
    pub lr_pow: u32,
    /// Training-set rows.
    pub rows: usize,
    /// Jobs (M) in the cluster phase.
    pub jobs: usize,
    /// Boards (F) in the cluster phase.
    pub boards: usize,
    /// Weight-sync cadence for divided placements.
    pub sync_every: usize,
    /// Weight-sync policy of the cluster phase. Deterministic policies
    /// (`Star`, `Ring`, `BoundedStale { max_lag: 0 }`) keep the
    /// bit-exact differential oracles; other `BoundedStale` lags use
    /// the loss-descent convergence oracle instead.
    pub sync: SyncPolicy,
}

impl FuzzCase {
    /// The learning rate encoded by `lr_pow`.
    pub fn lr(&self) -> f64 {
        1.0 / (1u64 << self.lr_pow) as f64
    }

    /// The training configuration of every level.
    pub fn train_config(&self) -> TrainConfig {
        TrainConfig {
            batch: self.net.batch,
            lr: self.lr(),
            steps: self.steps,
            seed: self.net.seed,
            log_every: 2,
        }
    }

    /// The deterministic dataset (classes = out_dim, dim = in_dim).
    pub fn dataset(&self) -> Dataset {
        let spec = self.net.spec();
        dataset::blobs(
            self.rows,
            spec.output_dim(),
            spec.input_dim(),
            self.net.seed ^ SALT_DATA,
        )
    }
}

pub(crate) fn sample_fuzz_case(r: &mut Rng) -> FuzzCase {
    let net = sample_net_case(r);
    let batch = net.batch;
    FuzzCase {
        net,
        steps: 1 + r.gen_range(8) as usize, // 1..=8
        lr_pow: 5 + r.gen_range(3) as u32,  // lr ∈ {1/32, 1/64, 1/128}
        // ≥ 2·batch rows, usually with a partial evaluation tail
        rows: batch * (2 + r.gen_range(4) as usize) + r.gen_range(3) as usize,
        jobs: 1 + r.gen_range(3) as usize,   // 1..=3
        boards: 1 + r.gen_range(3) as usize, // 1..=3
        sync_every: 1 + r.gen_range(4) as usize,
        sync: *r.choose(&[
            SyncPolicy::Star,
            SyncPolicy::Ring,
            SyncPolicy::BoundedStale { max_lag: 1 },
        ]),
    }
}

fn shrink_fuzz_case(c: &FuzzCase) -> Vec<FuzzCase> {
    let mut out: Vec<FuzzCase> = shrink_net_case(&c.net)
        .into_iter()
        .map(|net| FuzzCase { net, ..c.clone() })
        .collect();
    if c.steps > 1 {
        out.push(FuzzCase { steps: c.steps / 2, ..c.clone() });
    }
    if c.rows > 1 {
        out.push(FuzzCase { rows: c.rows / 2, ..c.clone() });
    }
    if c.jobs > 1 {
        out.push(FuzzCase { jobs: c.jobs - 1, ..c.clone() });
    }
    if c.boards > 1 {
        out.push(FuzzCase { boards: c.boards - 1, ..c.clone() });
    }
    if c.sync_every > 1 {
        out.push(FuzzCase { sync_every: 1, ..c.clone() });
    }
    // toward the star oracle (a policy-independent reproduction shrinks
    // away the policy dimension entirely)
    if c.sync != SyncPolicy::Star {
        out.push(FuzzCase { sync: SyncPolicy::Star, ..c.clone() });
    }
    out
}

/// Generator for [`FuzzCase`].
pub fn fuzz_case() -> Gen<FuzzCase> {
    Gen::new(sample_fuzz_case, shrink_fuzz_case)
}

// ---------------------------------------------------------- raw programs

/// Opcodes the raw-program generator draws from: every opcode with lane
/// semantics. `Nop` is excluded deliberately — it has no microcode
/// lowering (`MvmOp::from_opcode` rejects it), so a Nop wave cannot be
/// structurally verified.
const OPS: [Opcode; 6] = [
    Opcode::VectorAddition,
    Opcode::VectorSubtraction,
    Opcode::ElementMultiplication,
    Opcode::VectorDotProduct,
    Opcode::VectorSummation,
    Opcode::ActivationFunction,
];

/// A generated raw vector program + input bindings. Wave operand fields
/// are stored as raw draws and reduced modulo the current buffer count at
/// [`ProgramCase::build`] time, so shrinking `bufs`/`waves` never
/// invalidates the case.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramCase {
    /// Case seed (drives the bound data).
    pub seed: u64,
    /// Vector length of every buffer.
    pub len: usize,
    /// Number of data buffers (≥ 2; buffer 0 is input-only).
    pub bufs: usize,
    /// Wave descriptors: `(op draw, a draw, b draw, dst draw)`.
    pub waves: Vec<(usize, usize, usize, usize)>,
    /// Fractional bits.
    pub frac_bits: u32,
    /// Saturating vs wrapping narrowing.
    pub saturate: bool,
}

impl ProgramCase {
    /// Materialise the program and its deterministic input bindings.
    pub fn build(&self) -> (Program, Vec<(BufId, Vec<i16>)>) {
        let fixed = if self.saturate {
            FixedSpec::q(self.frac_bits).saturating()
        } else {
            FixedSpec::q(self.frac_bits)
        };
        let mut r = Rng::new(self.seed);
        let mut p = Program::new("fuzz_raw", fixed);
        let mut binds = Vec::new();
        for i in 0..self.bufs {
            let kind = if i == 0 { BufKind::Input } else { BufKind::Output };
            let id = p.buffer(&format!("buf{i}"), self.len, 1, kind);
            let data: Vec<i16> =
                (0..self.len).map(|_| r.gen_range_i64(-6000, 6000) as i16).collect();
            binds.push((id, data));
        }
        let scalar = p.buffer("scalar", self.bufs, 1, BufKind::Output);
        let lut = p.lut(
            ActLut::build(
                ActKind::Tanh,
                false,
                fixed,
                AddrMode::Clamp,
                self.frac_bits.saturating_sub(4),
            )
            .with_interp(),
        );
        p.steps.push(Step::LoadLut(lut));
        for (wi, &(op_d, a_d, b_d, dst_d)) in self.waves.iter().enumerate() {
            let op = OPS[op_d % OPS.len()];
            let a = a_d % self.bufs;
            let b = b_d % self.bufs;
            let dst = 1 + dst_d % (self.bufs - 1);
            let n = self.len;
            let lanes = match op {
                Opcode::VectorDotProduct | Opcode::VectorSummation => vec![LaneOp {
                    a: View::all(a, n),
                    b: (op == Opcode::VectorDotProduct).then(|| View::all(b, n)),
                    out: View::contiguous(scalar, wi % self.bufs, 1),
                }],
                Opcode::ActivationFunction => vec![LaneOp {
                    a: View::all(a, n),
                    b: None,
                    out: View::all(dst, n),
                }],
                _ => vec![LaneOp {
                    a: View::all(a, n),
                    b: Some(View::all(b, n)),
                    out: View::all(dst, n),
                }],
            };
            p.steps.push(Step::Wave(Wave {
                op,
                vec_len: n,
                lut: (op == Opcode::ActivationFunction).then_some(lut),
                lanes,
            }));
        }
        (p, binds)
    }
}

pub(crate) fn sample_program_case(r: &mut Rng) -> ProgramCase {
    let n_waves = 1 + r.gen_range(8) as usize; // 1..=8
    ProgramCase {
        seed: r.next_u64(),
        len: 4 + r.gen_range(45) as usize, // 4..=48
        bufs: 2 + r.gen_range(5) as usize, // 2..=6
        waves: (0..n_waves)
            .map(|_| {
                (
                    r.gen_range(64) as usize,
                    r.gen_range(64) as usize,
                    r.gen_range(64) as usize,
                    r.gen_range(64) as usize,
                )
            })
            .collect(),
        frac_bits: 7 + r.gen_range(5) as u32, // Q7..Q11
        saturate: r.gen_bool(0.5),
    }
}

fn shrink_program_case(c: &ProgramCase) -> Vec<ProgramCase> {
    let mut out = Vec::new();
    if c.waves.len() > 1 {
        let mut d = c.clone();
        d.waves.truncate(c.waves.len() / 2);
        out.push(d);
        let mut d = c.clone();
        d.waves.pop();
        out.push(d);
    }
    if c.len > 1 {
        out.push(ProgramCase { len: c.len / 2, ..c.clone() });
    }
    if c.bufs > 2 {
        out.push(ProgramCase { bufs: c.bufs - 1, ..c.clone() });
    }
    if !c.saturate {
        out.push(ProgramCase { saturate: true, ..c.clone() });
    }
    out
}

/// Generator for [`ProgramCase`].
pub fn program_case() -> Gen<ProgramCase> {
    Gen::new(sample_program_case, shrink_program_case)
}

// ------------------------------------------------------ checker scenarios

/// The defect a [`CheckCase`] plants — or `Clean`, wrapping a sampled
/// [`ProgramCase`] that must produce zero diagnostics and then execute
/// within the checker's certified per-lane ranges.
#[derive(Debug, Clone, PartialEq)]
pub enum CheckDefect {
    /// A wave reads a scratch (`BufKind::Temp`) lane nothing ever
    /// defined — the checker must flag `undefined-read`.
    UndefinedRead,
    /// A wrapping add of two large constants whose sum lies entirely
    /// outside `i16` — the checker must flag `guaranteed-overflow`.
    Overflow,
    /// A wavefront demanding more simultaneous ring slots than the
    /// modelled FIFO capacity — the checker must flag `ring-overrun`.
    RingOverrun,
    /// One wave whose second lane reads the first lane's output —
    /// the checker must flag `order-dependent` (RAW) at
    /// [`crate::analysis::CheckLevel::Strict`].
    Hazard,
    /// No defect planted.
    Clean(ProgramCase),
}

/// A generated static-checker scenario (DESIGN.md §Static analysis):
/// planted defects MUST be flagged (catch rate 100%), clean programs
/// MUST check clean at `Standard` and then run — at every raw-program
/// fidelity level — with every final lane inside the checker's
/// certified range.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckCase {
    /// Case seed (sizes the planted programs).
    pub seed: u64,
    /// What this case plants.
    pub defect: CheckDefect,
}

impl CheckCase {
    /// Materialise a planted-defect program: `(program, expected
    /// diagnostic kind, ring-capacity override)`. Panics on `Clean`
    /// (clean cases run through [`ProgramCase::build`]).
    pub fn build_planted(&self) -> (Program, &'static str, Option<usize>) {
        let mut r = Rng::new(self.seed);
        let n = 2 + r.gen_range(14) as usize; // 2..=15 lanes per buffer
        match self.defect {
            CheckDefect::UndefinedRead => {
                let mut p = Program::new("planted_undefined_read", FixedSpec::PAPER);
                let t = p.buffer("scratch", n, 1, BufKind::Temp);
                let o = p.buffer("out", n, 1, BufKind::Output);
                p.steps.push(Step::Wave(Wave {
                    op: Opcode::VectorAddition,
                    vec_len: n,
                    lut: None,
                    lanes: vec![LaneOp {
                        a: View::all(t, n),
                        b: Some(View::all(t, n)),
                        out: View::all(o, n),
                    }],
                }));
                (p, "undefined-read", None)
            }
            CheckDefect::Overflow => {
                // Wrap-mode adds don't rescale: big+big ∈ [50000, 63998]
                // lies outside i16 for every execution.
                let mut p = Program::new("planted_overflow", FixedSpec::q(7));
                let big = 25000 + r.gen_range(7000) as i16;
                let c = p.const_buffer("big", vec![big; n]);
                let o = p.buffer("out", n, 1, BufKind::Output);
                p.steps.push(Step::Wave(Wave {
                    op: Opcode::VectorAddition,
                    vec_len: n,
                    lut: None,
                    lanes: vec![LaneOp {
                        a: View::all(c, n),
                        b: Some(View::all(c, n)),
                        out: View::all(o, n),
                    }],
                }));
                (p, "guaranteed-overflow", None)
            }
            CheckDefect::RingOverrun => {
                // Two active MVM groups inject two simultaneous result
                // tokens; model a single-slot FIFO.
                let w = 2 * crate::hw::PROCS_PER_GROUP;
                let mut p = Program::new("planted_ring_overrun", FixedSpec::PAPER);
                let x = p.buffer("x", w, 1, BufKind::Input);
                let o = p.buffer("o", w, 1, BufKind::Output);
                p.steps.push(Step::Wave(Wave {
                    op: Opcode::VectorDotProduct,
                    vec_len: 1,
                    lut: None,
                    lanes: (0..w)
                        .map(|i| LaneOp {
                            a: View::contiguous(x, i, 1),
                            b: Some(View::contiguous(x, i, 1)),
                            out: View::contiguous(o, i, 1),
                        })
                        .collect(),
                }));
                (p, "ring-overrun", Some(1))
            }
            CheckDefect::Hazard => {
                // Lane 1 reads the arena address lane 0 just wrote —
                // a RAW hazard that makes the wave order-dependent.
                let mut p = Program::new("planted_hazard", FixedSpec::PAPER);
                let x = p.buffer("x", 2, 1, BufKind::Input);
                let y = p.buffer("y", 2, 1, BufKind::Output);
                p.steps.push(Step::Wave(Wave {
                    op: Opcode::VectorAddition,
                    vec_len: 1,
                    lut: None,
                    lanes: vec![
                        LaneOp {
                            a: View::contiguous(x, 0, 1),
                            b: Some(View::contiguous(x, 0, 1)),
                            out: View::contiguous(y, 0, 1),
                        },
                        LaneOp {
                            a: View::contiguous(y, 0, 1),
                            b: Some(View::contiguous(x, 1, 1)),
                            out: View::contiguous(y, 1, 1),
                        },
                    ],
                }));
                (p, "order-dependent", None)
            }
            CheckDefect::Clean(_) => {
                unreachable!("clean cases materialise via ProgramCase::build")
            }
        }
    }
}

pub(crate) fn sample_check_case(r: &mut Rng) -> CheckCase {
    let seed = r.next_u64();
    let defect = match r.gen_range(5) {
        0 => CheckDefect::UndefinedRead,
        1 => CheckDefect::Overflow,
        2 => CheckDefect::RingOverrun,
        3 => CheckDefect::Hazard,
        _ => CheckDefect::Clean(sample_program_case(r)),
    };
    CheckCase { seed, defect }
}

fn shrink_check_case(c: &CheckCase) -> Vec<CheckCase> {
    // Planted cases are already minimal; clean cases shrink with the
    // wrapped program.
    match &c.defect {
        CheckDefect::Clean(pc) => shrink_program_case(pc)
            .into_iter()
            .map(|pc| CheckCase { seed: c.seed, defect: CheckDefect::Clean(pc) })
            .collect(),
        _ => Vec::new(),
    }
}

/// Generator for [`CheckCase`].
pub fn check_case() -> Gen<CheckCase> {
    Gen::new(sample_check_case, shrink_check_case)
}

// -------------------------------------------------------- fault scenarios

/// A generated cluster fault scenario: a topology (reusing [`FuzzCase`])
/// plus a deterministic [`FaultPlan`] targeting it.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultCase {
    /// Topology + jobs.
    pub case: FuzzCase,
    /// The injected fault schedule.
    pub plan: FaultPlan,
}

pub(crate) fn sample_fault_case(r: &mut Rng) -> FaultCase {
    let case = sample_fuzz_case(r);
    let mut plan = FaultPlan::none();
    for _ in 0..r.gen_range(3) {
        // 0..=2 faults
        let board = r.gen_range(case.boards as u64) as usize;
        let at = r.gen_range(4) as usize;
        plan = match r.gen_range(4) {
            0 => plan.kill(board, at),
            1 => plan.corrupt(board, at),
            2 => plan.delay(board, at),
            _ => plan.reorder(board, at),
        };
    }
    FaultCase { case, plan }
}

fn shrink_fault_case(c: &FaultCase) -> Vec<FaultCase> {
    let mut out: Vec<FaultCase> = shrink_fuzz_case(&c.case)
        .into_iter()
        .map(|case| FaultCase { case, plan: c.plan.clone() })
        .collect();
    // drop one fault at a time
    for (list, strip) in [
        (&c.plan.kills, 0usize),
        (&c.plan.corruptions, 1),
        (&c.plan.delays, 2),
        (&c.plan.reorders, 3),
    ] {
        for i in 0..list.len() {
            let mut d = c.clone();
            match strip {
                0 => {
                    d.plan.kills.remove(i);
                }
                1 => {
                    d.plan.corruptions.remove(i);
                }
                2 => {
                    d.plan.delays.remove(i);
                }
                _ => {
                    d.plan.reorders.remove(i);
                }
            }
            out.push(d);
        }
    }
    out
}

/// Generator for [`FaultCase`].
pub fn fault_case() -> Gen<FaultCase> {
    Gen::new(sample_fault_case, shrink_fault_case)
}

// ------------------------------------------------------ recovery scenarios

/// A generated **survivable** fault scenario: a topology plus a
/// deterministic [`FaultPlan`] whose kills leave at least one board
/// alive in every recovery domain (the whole pool for sequential/1:1
/// placements, each board group for divided ones) and whose corruptions
/// stay within the retry budget. Under the default
/// [`crate::cluster::RecoveryPolicy`] such a run must **complete** with
/// results bit-identical to the fault-free run — the acceptance
/// property of the recovery subsystem ("kill up to F−1 boards mid-job
/// and still converge to the fault-free weights").
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryCase {
    /// Topology + jobs (boards forced ≥ 2 so a kill can be survivable).
    pub case: FuzzCase,
    /// The injected, survivable fault schedule.
    pub plan: FaultPlan,
}

pub(crate) fn sample_recovery_case(r: &mut Rng) -> RecoveryCase {
    let mut case = sample_fuzz_case(r);
    if case.boards < 2 {
        case.boards = 2;
    }
    let placement = schedule(case.jobs, case.boards);
    let mut plan = FaultPlan::none();
    let mut victims: Vec<usize> = Vec::new();
    match placement.mode {
        PlacementMode::Divided => {
            // Groups recover internally: keep each group's first board.
            for group in &placement.groups {
                for &b in group.iter().skip(1) {
                    if r.gen_bool(0.5) {
                        victims.push(b);
                    }
                }
            }
        }
        _ => {
            // Pool-wide recovery domain: keep board 0.
            for b in 1..case.boards {
                if r.gen_bool(0.5) {
                    victims.push(b);
                }
            }
        }
    }
    for &b in &victims {
        // Command indices 0..=5 cover setup, mid-chunk, and evaluate.
        plan = plan.kill(b, r.gen_range(6) as usize);
    }
    if r.gen_bool(0.5) {
        // One in-transit corruption anywhere: the bounded ReadParams
        // retry recovers it without evicting the board.
        let b = r.gen_range(case.boards as u64) as usize;
        plan = plan.corrupt(b, r.gen_range(2) as usize);
    }
    RecoveryCase { case, plan }
}

fn shrink_recovery_case(c: &RecoveryCase) -> Vec<RecoveryCase> {
    // Never shrink jobs/boards — that would change the recovery domains
    // and could turn a survivable plan into a legitimate abort.
    let mut out: Vec<RecoveryCase> = shrink_net_case(&c.case.net)
        .into_iter()
        .map(|net| RecoveryCase {
            case: FuzzCase { net, ..c.case.clone() },
            plan: c.plan.clone(),
        })
        .collect();
    if c.case.steps > 1 {
        out.push(RecoveryCase {
            case: FuzzCase { steps: c.case.steps / 2, ..c.case.clone() },
            plan: c.plan.clone(),
        });
    }
    if c.case.rows > 1 {
        out.push(RecoveryCase {
            case: FuzzCase { rows: c.case.rows / 2, ..c.case.clone() },
            plan: c.plan.clone(),
        });
    }
    if c.case.sync_every > 1 {
        out.push(RecoveryCase {
            case: FuzzCase { sync_every: 1, ..c.case.clone() },
            plan: c.plan.clone(),
        });
    }
    for i in 0..c.plan.kills.len() {
        let mut d = c.clone();
        d.plan.kills.remove(i);
        out.push(d);
    }
    for i in 0..c.plan.corruptions.len() {
        let mut d = c.clone();
        d.plan.corruptions.remove(i);
        out.push(d);
    }
    out
}

/// Generator for [`RecoveryCase`].
pub fn recovery_case() -> Gen<RecoveryCase> {
    Gen::new(sample_recovery_case, shrink_recovery_case)
}

// --------------------------------------------------- serve-chaos scenarios

/// A generated **survivable** serving fault scenario: a topology
/// (reusing [`FuzzCase`]: `boards` sizes the pool, the net is the
/// served artifact, `rows` the request count) plus a deterministic
/// [`ServeFaultPlan`] that never kills board 0 and keeps transient
/// sites within the default hedged-retry budget. Under such a plan the
/// serving runtime must terminate every admitted request as a
/// completion or a typed drop (shed / deadline-exceeded) — the serving
/// twin of the cluster's "leader never hangs" acceptance property —
/// and completed outputs must stay bit-identical to batch-1 inference.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeChaosCase {
    /// Topology + net (boards forced ≥ 2 so hedging has a survivor).
    pub case: FuzzCase,
    /// The injected, survivable serving fault schedule.
    pub plan: ServeFaultPlan,
}

/// The retry budget serve-chaos plans are generated against — the
/// default [`crate::serve::ServeConfig::max_retries`].
pub(crate) const SERVE_CHAOS_RETRIES: usize = 3;

pub(crate) fn sample_serve_chaos_case(r: &mut Rng) -> ServeChaosCase {
    let mut case = sample_fuzz_case(r);
    if case.boards < 2 {
        case.boards = 2;
    }
    let plan = ServeFaultPlan::survivable(r.next_u64(), case.boards, SERVE_CHAOS_RETRIES);
    ServeChaosCase { case, plan }
}

fn shrink_serve_chaos_case(c: &ServeChaosCase) -> Vec<ServeChaosCase> {
    // Never shrink boards — the plan's sites target specific boards and
    // shrinking the pool could make a survivable plan lethal.
    let mut out: Vec<ServeChaosCase> = shrink_net_case(&c.case.net)
        .into_iter()
        .map(|net| ServeChaosCase {
            case: FuzzCase { net, ..c.case.clone() },
            plan: c.plan.clone(),
        })
        .collect();
    if c.case.rows > 1 {
        out.push(ServeChaosCase {
            case: FuzzCase { rows: c.case.rows / 2, ..c.case.clone() },
            plan: c.plan.clone(),
        });
    }
    if c.case.sync_every > 1 {
        out.push(ServeChaosCase {
            case: FuzzCase { sync_every: 1, ..c.case.clone() },
            plan: c.plan.clone(),
        });
    }
    // drop one fault at a time (stays survivable: fewer faults)
    for i in 0..c.plan.stalls.len() {
        let mut d = c.clone();
        d.plan.stalls.remove(i);
        out.push(d);
    }
    for i in 0..c.plan.corruptions.len() {
        let mut d = c.clone();
        d.plan.corruptions.remove(i);
        out.push(d);
    }
    for i in 0..c.plan.deaths.len() {
        let mut d = c.clone();
        d.plan.deaths.remove(i);
        out.push(d);
    }
    out
}

/// Generator for [`ServeChaosCase`].
pub fn serve_chaos_case() -> Gen<ServeChaosCase> {
    Gen::new(sample_serve_chaos_case, shrink_serve_chaos_case)
}

// ------------------------------------------------------- memplan scenarios

/// A generated memory-planner case: one forward program — MLP-shaped or
/// operator-graph-shaped (the graph arm covers the CNN and
/// transformer-block archetypes whose many temporaries make lane reuse
/// interesting) — executed with the static memory planner on vs off.
/// The planner must be behaviour-invisible: bit-identical outputs,
/// identical [`crate::hw::RunStats`], and a planned arena never larger
/// than the packed one.
#[derive(Debug, Clone, PartialEq)]
pub enum MemplanCase {
    /// An MLP forward program.
    Net(NetCase),
    /// An operator-graph forward program.
    Graph(GraphCase),
}

pub(crate) fn sample_memplan_case(r: &mut Rng) -> MemplanCase {
    if r.gen_bool(0.5) {
        MemplanCase::Net(sample_net_case(r))
    } else {
        MemplanCase::Graph(sample_graph_case(r))
    }
}

fn shrink_memplan_case(c: &MemplanCase) -> Vec<MemplanCase> {
    match c {
        MemplanCase::Net(n) => shrink_net_case(n).into_iter().map(MemplanCase::Net).collect(),
        MemplanCase::Graph(g) => {
            shrink_graph_case(g).into_iter().map(MemplanCase::Graph).collect()
        }
    }
}

/// Generator for [`MemplanCase`].
pub fn memplan_case() -> Gen<MemplanCase> {
    Gen::new(sample_memplan_case, shrink_memplan_case)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_regenerate_exactly_from_a_seed() {
        for seed in [0u64, 1, 0xDEAD] {
            assert_eq!(
                sample_fuzz_case(&mut Rng::new(seed)),
                sample_fuzz_case(&mut Rng::new(seed))
            );
            assert_eq!(
                sample_program_case(&mut Rng::new(seed)),
                sample_program_case(&mut Rng::new(seed))
            );
            assert_eq!(
                sample_fault_case(&mut Rng::new(seed)),
                sample_fault_case(&mut Rng::new(seed))
            );
            assert_eq!(
                sample_recovery_case(&mut Rng::new(seed)),
                sample_recovery_case(&mut Rng::new(seed))
            );
            assert_eq!(
                sample_serve_chaos_case(&mut Rng::new(seed)),
                sample_serve_chaos_case(&mut Rng::new(seed))
            );
            assert_eq!(
                sample_graph_case(&mut Rng::new(seed)),
                sample_graph_case(&mut Rng::new(seed))
            );
            assert_eq!(
                sample_memplan_case(&mut Rng::new(seed)),
                sample_memplan_case(&mut Rng::new(seed))
            );
        }
    }

    #[test]
    fn generated_graphs_validate_and_derive_consistent_bindings() {
        let mut r = Rng::new(0x6AF);
        for _ in 0..80 {
            let c = sample_graph_case(&mut r);
            let spec = c.spec();
            spec.check().unwrap();
            let decls = spec.param_decls().unwrap();
            let (w, b) = c.params();
            assert_eq!(w.len(), decls.len());
            for (i, d) in decls.iter().enumerate() {
                assert_eq!(w[i].len(), d.rows * d.cols);
                assert_eq!(b[i].len(), d.cols);
            }
            assert_eq!(c.input().len(), c.batch * spec.input_dim());
            for s in shrink_graph_case(&c) {
                s.spec().check().unwrap();
                assert!(s != c, "shrink candidate equals original");
            }
        }
    }

    #[test]
    fn serve_chaos_cases_are_survivable_and_shrink_safely() {
        let mut r = Rng::new(0x5E1);
        for _ in 0..200 {
            let c = sample_serve_chaos_case(&mut r);
            assert!(c.case.boards >= 2);
            assert!(
                c.plan.is_survivable(c.case.boards, SERVE_CHAOS_RETRIES),
                "plan {:?} not survivable for {} boards",
                c.plan,
                c.case.boards
            );
            assert!(c.plan.deaths.iter().all(|s| s.board != 0), "board 0 must survive");
            for s in shrink_serve_chaos_case(&c) {
                assert_eq!(s.case.boards, c.case.boards, "shrinks keep the pool size");
                assert!(s.plan.is_survivable(s.case.boards, SERVE_CHAOS_RETRIES));
                assert!(s != c, "shrink candidate equals original");
            }
        }
    }

    #[test]
    fn recovery_cases_always_leave_a_survivor_per_domain() {
        let mut r = Rng::new(0xEC0);
        for _ in 0..200 {
            let c = sample_recovery_case(&mut r);
            assert!(c.case.boards >= 2);
            assert!(c.plan.reorders.is_empty(), "reorders are not survivable");
            let killed: Vec<usize> = c.plan.kills.iter().map(|s| s.board).collect();
            let placement = schedule(c.case.jobs, c.case.boards);
            match placement.mode {
                PlacementMode::Divided => {
                    for group in &placement.groups {
                        assert!(
                            group.iter().any(|b| !killed.contains(b)),
                            "group {group:?} fully killed by {killed:?}"
                        );
                    }
                }
                _ => {
                    assert!(
                        (0..c.case.boards).any(|b| !killed.contains(&b)),
                        "whole pool killed by {killed:?}"
                    );
                }
            }
            // at most one corruption site per case — within the default
            // retry budget, so never an eviction by itself
            assert!(c.plan.corruptions.len() <= 1);
            // shrinks keep the topology (and therefore survivability)
            for s in shrink_recovery_case(&c) {
                assert_eq!(s.case.jobs, c.case.jobs);
                assert_eq!(s.case.boards, c.case.boards);
            }
        }
    }

    #[test]
    fn generated_nets_validate_and_derive_consistent_bindings() {
        let mut r = Rng::new(42);
        for _ in 0..50 {
            let c = sample_net_case(&mut r);
            let spec = c.spec();
            spec.check().unwrap();
            let (w, b) = c.params();
            assert_eq!(w.len(), spec.layers.len());
            for (l, layer) in spec.layers.iter().enumerate() {
                assert_eq!(w[l].len(), layer.inputs * layer.outputs);
                assert_eq!(b[l].len(), layer.outputs);
            }
            assert_eq!(c.input().len(), c.batch * spec.input_dim());
            assert_eq!(c.targets().len(), c.batch * spec.output_dim());
        }
    }

    #[test]
    fn generated_programs_validate() {
        let mut r = Rng::new(7);
        for _ in 0..50 {
            let c = sample_program_case(&mut r);
            let (p, binds) = c.build();
            p.check().expect("generated program must validate");
            assert_eq!(binds.len(), c.bufs);
        }
    }

    #[test]
    fn shrinking_preserves_validity_and_reduces() {
        let mut r = Rng::new(9);
        for _ in 0..20 {
            let c = sample_fuzz_case(&mut r);
            for s in shrink_fuzz_case(&c) {
                s.net.spec().check().unwrap();
                assert!(s != c, "shrink candidate equals original");
            }
            let pc = sample_program_case(&mut r);
            for s in shrink_program_case(&pc) {
                s.build().0.check().unwrap();
            }
        }
    }

    #[test]
    fn net_case_shrinks_to_the_minimal_net() {
        // Greedy shrinking with an always-failing property bottoms out at
        // the 1→1 relu/identity net at batch 1.
        let mut c = sample_net_case(&mut Rng::new(3));
        loop {
            match shrink_net_case(&c).into_iter().next() {
                Some(next) => c = next,
                None => break,
            }
        }
        assert_eq!(c.dims, vec![1, 1]);
        assert_eq!(c.batch, 1);
        assert_eq!(c.act, ActKind::Relu);
        assert_eq!(c.out_act, ActKind::Identity);
    }
}
