//! ASCII table rendering — used to regenerate the paper's tables
//! (`mfnn tables`), print bench results, and write EXPERIMENTS.md sections.

use std::fmt::Write as _;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers (left-aligned).
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Table {
        let headers: Vec<String> = headers.into_iter().map(Into::into).collect();
        let aligns = vec![Align::Left; headers.len()];
        Table { title: None, headers, aligns, rows: Vec::new() }
    }

    /// Set a title printed above the table.
    pub fn with_title<S: Into<String>>(mut self, title: S) -> Table {
        self.title = Some(title.into());
        self
    }

    /// Set per-column alignment (length must match headers).
    pub fn with_aligns(mut self, aligns: Vec<Align>) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns;
        self
    }

    /// Right-align every column except the first.
    pub fn numeric(mut self) -> Table {
        for (i, a) in self.aligns.iter_mut().enumerate() {
            *a = if i == 0 { Align::Left } else { Align::Right };
        }
        self
    }

    /// Append a row. Panics if the column count mismatches.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Table {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string (box-drawing with `|` and `-`).
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            let _ = writeln!(out, "## {t}");
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let c = &cells[i];
                let pad = widths[i] - c.len();
                match aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(c);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(c);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };
        let _ = writeln!(out, "{sep}");
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &vec![Align::Left; ncol]));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &self.aligns));
        }
        let _ = writeln!(out, "{sep}");
        out
    }

    /// Render as GitHub-flavored markdown (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if let Some(t) = &self.title {
            let _ = writeln!(out, "### {t}\n");
        }
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let dashes: Vec<String> = self
            .aligns
            .iter()
            .map(|a| match a {
                Align::Left => "---".to_string(),
                Align::Right => "--:".to_string(),
            })
            .collect();
        let _ = writeln!(out, "| {} |", dashes.join(" | "));
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }
}

/// Format a float with `prec` decimals.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new(vec!["name", "value"]).numeric();
        t.row(vec!["alpha", "1"]);
        t.row(vec!["b", "1000"]);
        let s = t.render();
        assert!(s.contains("| alpha |     1 |"), "got:\n{s}");
        assert!(s.contains("| b     |  1000 |"), "got:\n{s}");
    }

    #[test]
    fn markdown_has_header_separator() {
        let mut t = Table::new(vec!["a", "b"]).numeric();
        t.row(vec!["x", "1"]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| --- | --: |"));
        assert!(md.contains("| x | 1 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(0.50123, 3), "0.501");
    }
}
