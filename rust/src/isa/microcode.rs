//! 32-bit microcode words (paper §3.3, Fig 3).
//!
//! "Each microcode controls 4 MVMs. The MVMs are arranged in groups of 4
//! because the 4:1 multiplexer is the most efficient multiplexer."
//!
//! Field layout straight from the prose of §3.3:
//!
//! | bits    | field                                    |
//! |---------|------------------------------------------|
//! | 9..0    | number of cycles                         |
//! | 10      | input column select                      |
//! | 11      | input counter enable                     |
//! | 12      | output column select                     |
//! | 13      | output counter enable                    |
//! | 15..14  | output 4:1 multiplexer select            |
//! | 31..16  | 4 × 4-bit processor control signals      |
//!
//! Each 4-bit processor-control nibble maps to one processor's
//! `processor_control` port: for an MVM that is the 3-bit [`MvmOp`] plus the
//! "Right BRAM MSB select" bit (Table 5); for an ACTPRO the low 2 bits are
//! the [`ActproOp`] (Table 7).

use super::opcode::{ActproOp, MvmOp};
use std::fmt;

/// Number of processors driven by one microcode word.
pub const PROCS_PER_GROUP: usize = 4;
/// Capacity of a processor group's microcode cache (§4.1: "stores 16
/// microcodes in total").
pub const MICROCODE_CACHE_DEPTH: usize = 16;
/// Maximum value of the 10-bit cycle field.
pub const MAX_CYCLES: u16 = (1 << 10) - 1;

/// One processor-control nibble inside a microcode word.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct ProcCtrl(pub u8);

impl ProcCtrl {
    /// Build from an MVM operation + right-BRAM MSB select bit.
    pub fn mvm(op: MvmOp, msb_select: bool) -> ProcCtrl {
        ProcCtrl(op.bits() | ((msb_select as u8) << 3))
    }

    /// Build from an Activation Processor operation.
    pub fn actpro(op: ActproOp) -> ProcCtrl {
        ProcCtrl(op.bits())
    }

    /// View the nibble as an MVM control (`processor_control(2..0)` +
    /// MSB-select bit 3).
    pub fn as_mvm(self) -> (MvmOp, bool) {
        (MvmOp::from_bits(self.0), self.0 & 0b1000 != 0)
    }

    /// View the nibble as an ACTPRO control (`processor_control(1..0)`).
    pub fn as_actpro(self) -> ActproOp {
        ActproOp::from_bits(self.0)
    }

    /// Raw nibble value (low 4 bits).
    pub fn bits(self) -> u8 {
        self.0 & 0xF
    }
}

/// A decoded 32-bit microcode word (Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Microcode {
    /// `microcode(9..0)` — number of cycles this word executes for.
    pub cycles: u16,
    /// `microcode(10)` — input column select (double-buffer column 0/1).
    pub input_col: bool,
    /// `microcode(11)` — input counter enable.
    pub input_ctr_en: bool,
    /// `microcode(12)` — output column select.
    pub output_col: bool,
    /// `microcode(13)` — output counter enable.
    pub output_ctr_en: bool,
    /// `microcode(15..14)` — output 4:1 multiplexer select.
    pub out_mux_sel: u8,
    /// `microcode(31..16)` — per-processor control nibbles.
    pub proc_ctrl: [ProcCtrl; PROCS_PER_GROUP],
}

impl Microcode {
    /// Encode to the 32-bit word. Panics in debug if fields exceed their
    /// widths (callers validate; the assembler never produces oversize
    /// fields because [`Microcode::with_cycles`] checks).
    pub fn encode(&self) -> u32 {
        debug_assert!(self.cycles <= MAX_CYCLES);
        debug_assert!(self.out_mux_sel < 4);
        let mut w = (self.cycles & 0x3FF) as u32;
        w |= (self.input_col as u32) << 10;
        w |= (self.input_ctr_en as u32) << 11;
        w |= (self.output_col as u32) << 12;
        w |= (self.output_ctr_en as u32) << 13;
        w |= ((self.out_mux_sel & 0b11) as u32) << 14;
        for (i, pc) in self.proc_ctrl.iter().enumerate() {
            w |= (pc.bits() as u32) << (16 + 4 * i);
        }
        w
    }

    /// Decode from a 32-bit word. Total: every `u32` decodes.
    pub fn decode(w: u32) -> Microcode {
        let mut proc_ctrl = [ProcCtrl::default(); PROCS_PER_GROUP];
        for (i, pc) in proc_ctrl.iter_mut().enumerate() {
            *pc = ProcCtrl(((w >> (16 + 4 * i)) & 0xF) as u8);
        }
        Microcode {
            cycles: (w & 0x3FF) as u16,
            input_col: w & (1 << 10) != 0,
            input_ctr_en: w & (1 << 11) != 0,
            output_col: w & (1 << 12) != 0,
            output_ctr_en: w & (1 << 13) != 0,
            out_mux_sel: ((w >> 14) & 0b11) as u8,
            proc_ctrl,
        }
    }

    /// Builder: set cycle count, checking the 10-bit limit.
    pub fn with_cycles(mut self, cycles: u16) -> Microcode {
        assert!(cycles <= MAX_CYCLES, "cycle count {cycles} exceeds 10-bit field");
        self.cycles = cycles;
        self
    }

    /// Builder: same control nibble for all four processors.
    pub fn broadcast(mut self, pc: ProcCtrl) -> Microcode {
        self.proc_ctrl = [pc; PROCS_PER_GROUP];
        self
    }
}

impl fmt::Display for Microcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "uc[cyc={} icol={} ictr={} ocol={} octr={} mux={} pc={:X?}]",
            self.cycles,
            self.input_col as u8,
            self.input_ctr_en as u8,
            self.output_col as u8,
            self.output_ctr_en as u8,
            self.out_mux_sel,
            [
                self.proc_ctrl[0].bits(),
                self.proc_ctrl[1].bits(),
                self.proc_ctrl[2].bits(),
                self.proc_ctrl[3].bits()
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn field_layout_matches_fig3() {
        let m = Microcode {
            cycles: 0x3FF,
            input_col: false,
            input_ctr_en: false,
            output_col: false,
            output_ctr_en: false,
            out_mux_sel: 0,
            proc_ctrl: [ProcCtrl(0); 4],
        };
        assert_eq!(m.encode(), 0x0000_03FF);

        let m = Microcode { cycles: 0, input_col: true, ..Default::default() };
        assert_eq!(m.encode(), 1 << 10);
        let m = Microcode { input_ctr_en: true, ..Default::default() };
        assert_eq!(m.encode(), 1 << 11);
        let m = Microcode { output_col: true, ..Default::default() };
        assert_eq!(m.encode(), 1 << 12);
        let m = Microcode { output_ctr_en: true, ..Default::default() };
        assert_eq!(m.encode(), 1 << 13);
        let m = Microcode { out_mux_sel: 0b11, ..Default::default() };
        assert_eq!(m.encode(), 0b11 << 14);
        let m = Microcode {
            proc_ctrl: [ProcCtrl(0xF), ProcCtrl(0), ProcCtrl(0), ProcCtrl(0)],
            ..Default::default()
        };
        assert_eq!(m.encode(), 0xF << 16);
        let m = Microcode {
            proc_ctrl: [ProcCtrl(0), ProcCtrl(0), ProcCtrl(0), ProcCtrl(0xF)],
            ..Default::default()
        };
        assert_eq!(m.encode(), 0xF000_0000);
    }

    #[test]
    fn decode_is_total_and_roundtrips() {
        let mut r = Rng::new(99);
        for _ in 0..10_000 {
            let w = r.next_u32();
            let m = Microcode::decode(w);
            assert_eq!(m.encode(), w, "word {w:#010x} must survive decode→encode");
        }
    }

    #[test]
    fn proc_ctrl_mvm_view() {
        let pc = ProcCtrl::mvm(MvmOp::VecDot, true);
        assert_eq!(pc.bits(), 0b1011);
        assert_eq!(pc.as_mvm(), (MvmOp::VecDot, true));
        let pc = ProcCtrl::mvm(MvmOp::Write, false);
        assert_eq!(pc.as_mvm(), (MvmOp::Write, false));
    }

    #[test]
    fn proc_ctrl_actpro_view() {
        for op in ActproOp::ALL {
            assert_eq!(ProcCtrl::actpro(op).as_actpro(), op);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds 10-bit field")]
    fn with_cycles_checks_range() {
        let _ = Microcode::default().with_cycles(1024);
    }

    #[test]
    fn cache_depth_matches_paper() {
        // §4.1: "The microcode cache stores 16 microcodes in total."
        assert_eq!(MICROCODE_CACHE_DEPTH, 16);
    }
}
