//! Operation codes: the instruction-level opcodes (Table 2) and the
//! per-processor control encodings for MVMs (Table 6) and Activation
//! Processors (Table 7).

use std::fmt;

/// Instruction-level operation codes (paper Table 2, 3 bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    /// `000` — vector dot product.
    VectorDotProduct = 0b000,
    /// `001` — vector summation.
    VectorSummation = 0b001,
    /// `010` — vector addition.
    VectorAddition = 0b010,
    /// `011` — vector subtraction.
    VectorSubtraction = 0b011,
    /// `100` — element-wise multiplication.
    ElementMultiplication = 0b100,
    /// `101` — apply activation function to vectors.
    ActivationFunction = 0b101,
    /// `110` — no operation.
    Nop = 0b110,
}

impl Opcode {
    /// All opcodes, in Table 2 order.
    pub const ALL: [Opcode; 7] = [
        Opcode::VectorDotProduct,
        Opcode::VectorSummation,
        Opcode::VectorAddition,
        Opcode::VectorSubtraction,
        Opcode::ElementMultiplication,
        Opcode::ActivationFunction,
        Opcode::Nop,
    ];

    /// Decode a 3-bit field. `111` is reserved/invalid.
    pub fn from_bits(bits: u8) -> Option<Opcode> {
        Opcode::ALL.into_iter().find(|o| *o as u8 == bits & 0b111)
    }

    /// The 3-bit encoding.
    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Table 2 mnemonic.
    pub fn mnemonic(self) -> &'static str {
        match self {
            Opcode::VectorDotProduct => "VECTOR_DOT_PRODUCT",
            Opcode::VectorSummation => "VECTOR_SUMMATION",
            Opcode::VectorAddition => "VECTOR_ADDITION",
            Opcode::VectorSubtraction => "VECTOR_SUBTRACTION",
            Opcode::ElementMultiplication => "ELEMENT_MULTIPLICATION",
            Opcode::ActivationFunction => "ACTIVATION_FUNCTION",
            Opcode::Nop => "NOP",
        }
    }

    /// Table 2 description column.
    pub fn description(self) -> &'static str {
        match self {
            Opcode::VectorDotProduct => "Vector dot product",
            Opcode::VectorSummation => "Vector summation",
            Opcode::VectorAddition => "Vector addition",
            Opcode::VectorSubtraction => "Vector subtraction",
            Opcode::ElementMultiplication => "Element wise multiplication",
            Opcode::ActivationFunction => "Apply activation function to vectors",
            Opcode::Nop => "No operation",
        }
    }

    /// Does this instruction run on MVM processor groups (vs ACTPRO groups)?
    pub fn is_mvm(self) -> bool {
        !matches!(self, Opcode::ActivationFunction | Opcode::Nop)
    }
}

impl fmt::Display for Opcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.mnemonic())
    }
}

/// Mini Vector Machine processor control, `processor_control(2..0)`
/// (paper Table 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum MvmOp {
    /// `000` — reset all registers.
    Reset = 0b000,
    /// `001` — BRAM read (idle/halted state in Fig 7).
    Read = 0b001,
    /// `010` — BRAM write.
    Write = 0b010,
    /// `011` — vector dot product using BRAM.
    VecDot = 0b011,
    /// `100` — vector summation using BRAM.
    VecSum = 0b100,
    /// `101` — vector addition using BRAM.
    VecAdd = 0b101,
    /// `110` — vector subtraction using BRAM.
    VecSub = 0b110,
    /// `111` — element-wise multiplication.
    ElemMult = 0b111,
}

impl MvmOp {
    /// All MVM control values, in Table 6 order.
    pub const ALL: [MvmOp; 8] = [
        MvmOp::Reset,
        MvmOp::Read,
        MvmOp::Write,
        MvmOp::VecDot,
        MvmOp::VecSum,
        MvmOp::VecAdd,
        MvmOp::VecSub,
        MvmOp::ElemMult,
    ];

    /// Decode the 3-bit field (total).
    pub fn from_bits(bits: u8) -> MvmOp {
        Self::ALL[(bits & 0b111) as usize]
    }

    /// The 3-bit encoding.
    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Table 6 mnemonic.
    pub fn mnemonic(self) -> &'static str {
        match self {
            MvmOp::Reset => "MVM_RESET",
            MvmOp::Read => "MVM_READ",
            MvmOp::Write => "MVM_WRITE",
            MvmOp::VecDot => "MVM_VEC_DOT",
            MvmOp::VecSum => "MVM_VEC_SUM",
            MvmOp::VecAdd => "MVM_VEC_ADD",
            MvmOp::VecSub => "MVM_VEC_SUB",
            MvmOp::ElemMult => "MVM_ELEM_MUTLI", // sic — paper's spelling
        }
    }

    /// Is this an arithmetic (DSP-engaging) operation?
    pub fn is_compute(self) -> bool {
        matches!(
            self,
            MvmOp::VecDot | MvmOp::VecSum | MvmOp::VecAdd | MvmOp::VecSub | MvmOp::ElemMult
        )
    }

    /// The instruction opcode this control value implements, if any.
    pub fn opcode(self) -> Option<Opcode> {
        match self {
            MvmOp::VecDot => Some(Opcode::VectorDotProduct),
            MvmOp::VecSum => Some(Opcode::VectorSummation),
            MvmOp::VecAdd => Some(Opcode::VectorAddition),
            MvmOp::VecSub => Some(Opcode::VectorSubtraction),
            MvmOp::ElemMult => Some(Opcode::ElementMultiplication),
            _ => None,
        }
    }

    /// The MVM control value implementing an instruction opcode.
    pub fn from_opcode(op: Opcode) -> Option<MvmOp> {
        match op {
            Opcode::VectorDotProduct => Some(MvmOp::VecDot),
            Opcode::VectorSummation => Some(MvmOp::VecSum),
            Opcode::VectorAddition => Some(MvmOp::VecAdd),
            Opcode::VectorSubtraction => Some(MvmOp::VecSub),
            Opcode::ElementMultiplication => Some(MvmOp::ElemMult),
            Opcode::ActivationFunction | Opcode::Nop => None,
        }
    }
}

impl fmt::Display for MvmOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.mnemonic())
    }
}

/// Activation Processor control, `processor_control(1..0)` (paper Table 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum ActproOp {
    /// `00` — read BRAM.
    Read = 0b00,
    /// `01` — write activation function table to BRAM.
    WriteAct = 0b01,
    /// `10` — write input data to BRAM.
    WriteData = 0b10,
    /// `11` — bit shift and activation function.
    Run = 0b11,
}

impl ActproOp {
    /// All ACTPRO control values, in Table 7 order.
    pub const ALL: [ActproOp; 4] =
        [ActproOp::Read, ActproOp::WriteAct, ActproOp::WriteData, ActproOp::Run];

    /// Decode the 2-bit field (total).
    pub fn from_bits(bits: u8) -> ActproOp {
        Self::ALL[(bits & 0b11) as usize]
    }

    /// The 2-bit encoding.
    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Table 7 mnemonic.
    pub fn mnemonic(self) -> &'static str {
        match self {
            ActproOp::Read => "ACTPRO_READ",
            ActproOp::WriteAct => "ACTPRO_WRITE_ACT",
            ActproOp::WriteData => "ACTPRO_WRITE_DATA",
            ActproOp::Run => "ACTPRO_RUN",
        }
    }
}

impl fmt::Display for ActproOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.mnemonic())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_encodings_match_table2() {
        assert_eq!(Opcode::VectorDotProduct.bits(), 0b000);
        assert_eq!(Opcode::VectorSummation.bits(), 0b001);
        assert_eq!(Opcode::VectorAddition.bits(), 0b010);
        assert_eq!(Opcode::VectorSubtraction.bits(), 0b011);
        assert_eq!(Opcode::ElementMultiplication.bits(), 0b100);
        assert_eq!(Opcode::ActivationFunction.bits(), 0b101);
        assert_eq!(Opcode::Nop.bits(), 0b110);
    }

    #[test]
    fn opcode_roundtrip_and_reserved() {
        for op in Opcode::ALL {
            assert_eq!(Opcode::from_bits(op.bits()), Some(op));
        }
        assert_eq!(Opcode::from_bits(0b111), None);
    }

    #[test]
    fn mvm_op_encodings_match_table6() {
        assert_eq!(MvmOp::Reset.bits(), 0b000);
        assert_eq!(MvmOp::Read.bits(), 0b001);
        assert_eq!(MvmOp::Write.bits(), 0b010);
        assert_eq!(MvmOp::VecDot.bits(), 0b011);
        assert_eq!(MvmOp::VecSum.bits(), 0b100);
        assert_eq!(MvmOp::VecAdd.bits(), 0b101);
        assert_eq!(MvmOp::VecSub.bits(), 0b110);
        assert_eq!(MvmOp::ElemMult.bits(), 0b111);
    }

    #[test]
    fn mvm_op_total_roundtrip() {
        for bits in 0..8u8 {
            assert_eq!(MvmOp::from_bits(bits).bits(), bits);
        }
    }

    #[test]
    fn actpro_op_encodings_match_table7() {
        assert_eq!(ActproOp::Read.bits(), 0b00);
        assert_eq!(ActproOp::WriteAct.bits(), 0b01);
        assert_eq!(ActproOp::WriteData.bits(), 0b10);
        assert_eq!(ActproOp::Run.bits(), 0b11);
        for bits in 0..4u8 {
            assert_eq!(ActproOp::from_bits(bits).bits(), bits);
        }
    }

    #[test]
    fn opcode_mvmop_mapping_is_inverse() {
        for op in Opcode::ALL {
            if let Some(m) = MvmOp::from_opcode(op) {
                assert_eq!(m.opcode(), Some(op));
                assert!(op.is_mvm());
            } else {
                assert!(!op.is_mvm());
            }
        }
    }
}
