//! Instruction word encodings (paper §3.2, Fig 2).
//!
//! An instruction applies one [`Opcode`] to a contiguous range of processor
//! groups, repeated for a number of iterations. The paper describes two
//! encodings and gives their group capacities; the exact field order in
//! Fig 2 is an image we reconstruct as (LSB→MSB): opcode, processor select
//! start, processor select end, number of iterations.
//!
//! * **32-bit**: 3-bit opcode, 2 × 7-bit selects ("only control a maximum of
//!   128 processor groups"), 15-bit iteration count.
//! * **48-bit**: 3-bit opcode, 2 × 10-bit selects ("a maximum of 1024
//!   processor groups"), 25-bit iteration count.

use super::opcode::Opcode;
use std::fmt;
use thiserror::Error;

/// Instruction word width (Fig 2 shows both variants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Width {
    /// 32-bit words; ≤128 processor groups, ≤2¹⁵−1 iterations.
    W32,
    /// 48-bit words; ≤1024 processor groups, ≤2²⁵−1 iterations.
    W48,
}

impl Width {
    /// Bits in one processor-select field.
    pub fn select_bits(self) -> u32 {
        match self {
            Width::W32 => 7,
            Width::W48 => 10,
        }
    }

    /// Bits in the iteration-count field.
    pub fn iter_bits(self) -> u32 {
        match self {
            Width::W32 => 15,
            Width::W48 => 25,
        }
    }

    /// Maximum number of addressable processor groups.
    pub fn max_groups(self) -> u32 {
        1 << self.select_bits()
    }

    /// Maximum iteration count.
    pub fn max_iterations(self) -> u32 {
        (1 << self.iter_bits()) - 1
    }

    /// Total bits of the encoding.
    pub fn bits(self) -> u32 {
        match self {
            Width::W32 => 32,
            Width::W48 => 48,
        }
    }
}

/// Errors from instruction encoding/decoding.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum InstructionError {
    /// A processor-select value exceeds the width's field capacity.
    #[error("processor select {0} exceeds {1} groups for this width")]
    SelectOutOfRange(u16, u32),
    /// The iteration count exceeds the width's field capacity.
    #[error("iteration count {0} exceeds maximum {1} for this width")]
    IterationsOutOfRange(u32, u32),
    /// start > end.
    #[error("processor select start {0} > end {1}")]
    InvertedRange(u16, u16),
    /// Reserved opcode bits (`111`).
    #[error("reserved opcode bits 0b111")]
    ReservedOpcode,
    /// Bits above the encoding width are set.
    #[error("raw word has bits set above bit {0}")]
    ExcessBits(u32),
}

/// One Matrix Machine instruction (paper Table 2 + Fig 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Instruction {
    /// Operation applied by the selected processor groups.
    pub op: Opcode,
    /// First processor group the operation applies to (inclusive).
    pub proc_start: u16,
    /// Last processor group the operation applies to (inclusive).
    pub proc_end: u16,
    /// Number of loop iterations ("the number of iterations controls the
    /// number of loops").
    pub iterations: u32,
}

impl Instruction {
    /// Convenience constructor.
    pub fn new(op: Opcode, proc_start: u16, proc_end: u16, iterations: u32) -> Instruction {
        Instruction { op, proc_start, proc_end, iterations }
    }

    /// A NOP touching no groups.
    pub fn nop() -> Instruction {
        Instruction::new(Opcode::Nop, 0, 0, 0)
    }

    /// Number of processor groups selected (inclusive range).
    pub fn group_count(&self) -> u32 {
        (self.proc_end as u32).saturating_sub(self.proc_start as u32) + 1
    }

    /// Encode into the low bits of a `u64` for the given width.
    ///
    /// Layout (LSB→MSB): `op[3] | proc_start[S] | proc_end[S] | iterations[I]`
    /// where `S = select_bits`, `I = iter_bits`.
    pub fn encode(&self, width: Width) -> Result<u64, InstructionError> {
        if self.proc_start > self.proc_end {
            return Err(InstructionError::InvertedRange(self.proc_start, self.proc_end));
        }
        let s = width.select_bits();
        if self.proc_end as u32 >= width.max_groups() {
            return Err(InstructionError::SelectOutOfRange(self.proc_end, width.max_groups()));
        }
        if self.iterations > width.max_iterations() {
            return Err(InstructionError::IterationsOutOfRange(
                self.iterations,
                width.max_iterations(),
            ));
        }
        let mut w: u64 = self.op.bits() as u64;
        w |= (self.proc_start as u64) << 3;
        w |= (self.proc_end as u64) << (3 + s);
        w |= (self.iterations as u64) << (3 + 2 * s);
        Ok(w)
    }

    /// Decode from a raw word for the given width.
    pub fn decode(raw: u64, width: Width) -> Result<Instruction, InstructionError> {
        if width.bits() < 64 && raw >> width.bits() != 0 {
            return Err(InstructionError::ExcessBits(width.bits()));
        }
        let op =
            Opcode::from_bits((raw & 0b111) as u8).ok_or(InstructionError::ReservedOpcode)?;
        let s = width.select_bits();
        let sel_mask = (1u64 << s) - 1;
        let proc_start = ((raw >> 3) & sel_mask) as u16;
        let proc_end = ((raw >> (3 + s)) & sel_mask) as u16;
        if proc_start > proc_end {
            return Err(InstructionError::InvertedRange(proc_start, proc_end));
        }
        let iter_mask = (1u64 << width.iter_bits()) - 1;
        let iterations = ((raw >> (3 + 2 * s)) & iter_mask) as u32;
        Ok(Instruction { op, proc_start, proc_end, iterations })
    }
}

impl fmt::Display for Instruction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} pg[{}..={}] x{}",
            self.op.mnemonic(),
            self.proc_start,
            self.proc_end,
            self.iterations
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Instruction {
        Instruction::new(Opcode::VectorAddition, 3, 17, 1024)
    }

    #[test]
    fn capacities_match_paper() {
        // §3.2: "For the 32 bit version, the instructions only control a
        // maximum of 128 processor groups. For the 48 bit version ... 1024."
        assert_eq!(Width::W32.max_groups(), 128);
        assert_eq!(Width::W48.max_groups(), 1024);
        // Field budget exactly fills the word: 3 + 2*S + I == width.
        assert_eq!(3 + 2 * Width::W32.select_bits() + Width::W32.iter_bits(), 32);
        assert_eq!(3 + 2 * Width::W48.select_bits() + Width::W48.iter_bits(), 48);
    }

    #[test]
    fn roundtrip_w32_and_w48() {
        for width in [Width::W32, Width::W48] {
            let i = sample();
            let raw = i.encode(width).unwrap();
            assert!(raw >> width.bits() == 0);
            assert_eq!(Instruction::decode(raw, width).unwrap(), i);
        }
    }

    #[test]
    fn rejects_out_of_range() {
        let mut i = sample();
        i.proc_end = 200; // > 127
        assert_eq!(
            i.encode(Width::W32),
            Err(InstructionError::SelectOutOfRange(200, 128))
        );
        assert!(i.encode(Width::W48).is_ok());

        let mut j = sample();
        j.iterations = 40_000; // > 2^15-1
        assert!(matches!(
            j.encode(Width::W32),
            Err(InstructionError::IterationsOutOfRange(40_000, _))
        ));
        assert!(j.encode(Width::W48).is_ok());
    }

    #[test]
    fn rejects_inverted_range_both_ways() {
        let i = Instruction::new(Opcode::Nop, 5, 2, 0);
        assert_eq!(i.encode(Width::W32), Err(InstructionError::InvertedRange(5, 2)));
        // raw word with start=5 end=2
        let raw: u64 = Opcode::Nop.bits() as u64 | (5 << 3) | (2 << 10);
        assert_eq!(
            Instruction::decode(raw, Width::W32),
            Err(InstructionError::InvertedRange(5, 2))
        );
    }

    #[test]
    fn rejects_reserved_opcode_and_excess_bits() {
        assert_eq!(Instruction::decode(0b111, Width::W32), Err(InstructionError::ReservedOpcode));
        assert_eq!(
            Instruction::decode(1u64 << 32, Width::W32),
            Err(InstructionError::ExcessBits(32))
        );
        assert_eq!(
            Instruction::decode(1u64 << 48, Width::W48),
            Err(InstructionError::ExcessBits(48))
        );
    }

    #[test]
    fn max_values_roundtrip() {
        for width in [Width::W32, Width::W48] {
            let i = Instruction::new(
                Opcode::ElementMultiplication,
                0,
                (width.max_groups() - 1) as u16,
                width.max_iterations(),
            );
            let raw = i.encode(width).unwrap();
            assert_eq!(Instruction::decode(raw, width).unwrap(), i);
        }
    }

    #[test]
    fn group_count() {
        assert_eq!(sample().group_count(), 15);
        assert_eq!(Instruction::nop().group_count(), 1);
    }

    #[test]
    fn display_is_readable() {
        assert_eq!(format!("{}", sample()), "VECTOR_ADDITION pg[3..=17] x1024");
    }
}
