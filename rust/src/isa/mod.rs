//! Instruction-set architecture of the Matrix Machine (paper §3.2–§3.3).
//!
//! Two artifact levels, exactly as the paper describes:
//!
//! * **Instructions** ([`instruction`]) — what the Matrix Assembler emits and
//!   the instruction cache stores (Table 2, Fig 2). Available in a 32-bit
//!   encoding (≤128 processor groups) and a 48-bit encoding (≤1024 groups).
//!   At runtime the global controller *decodes instructions into microcode*.
//! * **Microcode** ([`microcode`]) — 32-bit words, each driving one processor
//!   group of 4 processors (Fig 3): cycle count, input/output column
//!   selects, counter enables, output-mux select, and four 4-bit
//!   per-processor control nibbles (Tables 6–7).

pub mod instruction;
pub mod microcode;
pub mod opcode;

pub use instruction::{Instruction, InstructionError, Width};
pub use microcode::{Microcode, ProcCtrl};
pub use opcode::{ActproOp, MvmOp, Opcode};
