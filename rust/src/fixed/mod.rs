//! 16-bit signed fixed-point arithmetic — the single source of truth for the
//! Matrix Machine's datapath numerics (paper §2, §4.2).
//!
//! The paper's DSPs "are set to process 16 bit signed integers"; the DSP48E1
//! produces a 48-bit result that "is truncated into a 16 bit signed integer",
//! and the Activation Processor applies a 7-bit right shift before its BRAM
//! table lookup. We model this as a `Q(16, F)` format (default `F = 7`,
//! i.e. Q8.7): a lane value `v: i16` represents the real number `v / 2^F`.
//!
//! Semantics shared bit-exactly by the cycle-accurate simulator
//! ([`crate::hw`]), the fast functional simulator, the pure-jnp reference
//! (`python/compile/kernels/ref.py`) and the Pallas kernel
//! (`python/compile/kernels/mvm_layer.py`):
//!
//! * `ADD`/`SUB`/`SUM` — operate on Q.F values directly; results wrap (or
//!   saturate, see [`RoundMode`]) to 16 bits. No shift: Q.F + Q.F = Q.F.
//! * `ELEM_MULT`/`DOT` — products are Q.2F; the 48-bit accumulator result is
//!   shifted right by `F` (arithmetic) and then narrowed to 16 bits. This is
//!   the "truncate 48 → 16" step of §4.2 interpreted as taking the Q.F
//!   window (see DESIGN.md §3 deviation note; the low-16-bits reading cannot
//!   train and is therefore rejected).
//!
//! **Rounding rule (documented floor).** The Q.2F → Q.F rescale is an
//! *arithmetic* right shift, i.e. floor division by `2^F`: negative
//! products round toward −∞, so `mul(a, b)` and `-mul(-a, b)` may differ
//! by one ULP. This is deliberately the plain wire truncation the DSP48
//! slice performs — a round-half-up stage would cost an adder per lane
//! and break bit-compatibility with the VHDL and the Pallas kernels. The
//! rule lives in exactly one place, [`FixedSpec::rescale`]; every
//! simulator level (FastSim, ExecPlan, the structural MVM/DSP model) and
//! [`FixedSpec::mul`]/[`FixedSpec::dot`] call it, and the float oracle's
//! tolerance band absorbs the ≤ 1 ULP floor bias
//! (`tests/properties.rs::fixed_rescale_is_floor_division_for_signed_products`).
//!
//! [`RoundMode::Wrap`] is the paper-accurate hardware behaviour (a plain bus
//! truncation); [`RoundMode::Saturate`] is the ablation alternative
//! (`benches/bench_ablation.rs`).

/// How a wide accumulator value is narrowed to 16 bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoundMode {
    /// Keep the low 16 bits (two's-complement wraparound) — what a plain
    /// wire truncation in the VHDL does.
    Wrap,
    /// Clamp to `[i16::MIN, i16::MAX]` — costs a comparator tree in hardware
    /// but avoids catastrophic sign flips near the range edges.
    Saturate,
}

/// Fixed-point format + narrowing behaviour for one Matrix Machine datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FixedSpec {
    /// Number of fractional bits `F` in the Q(16, F) format.
    pub frac_bits: u32,
    /// Narrowing behaviour of the 48→16 truncation stage.
    pub round: RoundMode,
}

impl FixedSpec {
    /// The paper's configuration: Q8.7, plain truncation.
    pub const PAPER: FixedSpec = FixedSpec { frac_bits: 7, round: RoundMode::Wrap };

    /// Create a spec with the given fraction bits and wrap narrowing.
    pub fn q(frac_bits: u32) -> FixedSpec {
        assert!(frac_bits < 16, "frac_bits must be < 16");
        FixedSpec { frac_bits, round: RoundMode::Wrap }
    }

    /// Same format with saturating narrowing.
    pub fn saturating(self) -> FixedSpec {
        FixedSpec { round: RoundMode::Saturate, ..self }
    }

    /// The real-value scale `2^F`.
    pub fn scale(&self) -> f64 {
        (1u32 << self.frac_bits) as f64
    }

    /// Smallest representable positive step (`1 / 2^F`).
    pub fn resolution(&self) -> f64 {
        1.0 / self.scale()
    }

    /// Narrow a wide accumulator value to 16 bits per [`RoundMode`].
    #[inline]
    pub fn narrow(&self, acc: i64) -> i16 {
        match self.round {
            RoundMode::Wrap => acc as i16,
            RoundMode::Saturate => acc.clamp(i16::MIN as i64, i16::MAX as i64) as i16,
        }
    }

    /// The Q.2F → Q.F rescale + narrow stage: arithmetic shift right by
    /// `F` (**floor** division — negative accumulators round toward −∞,
    /// see the module docs for why), then [`FixedSpec::narrow`]. The
    /// single definition of the product rounding rule, shared by
    /// [`FixedSpec::mul`]/[`FixedSpec::dot`], FastSim, the compiled
    /// ExecPlan, and the structural MVM/DSP model.
    #[inline]
    pub fn rescale(&self, acc: i64) -> i16 {
        self.narrow(acc >> self.frac_bits)
    }

    /// Encode a real number into Q.F (round-to-nearest, then narrow).
    pub fn from_f64(&self, x: f64) -> i16 {
        self.narrow((x * self.scale()).round() as i64)
    }

    /// Decode a Q.F lane into a real number.
    pub fn to_f64(&self, v: i16) -> f64 {
        v as f64 / self.scale()
    }

    /// Encode a slice of reals.
    pub fn encode_vec(&self, xs: &[f64]) -> Vec<i16> {
        xs.iter().map(|&x| self.from_f64(x)).collect()
    }

    /// Decode a slice of lanes.
    pub fn decode_vec(&self, vs: &[i16]) -> Vec<f64> {
        vs.iter().map(|&v| self.to_f64(v)).collect()
    }

    // ---- lane ops (what one MVM does per element) ----

    /// Lane addition (`MVM_VEC_ADD` element step).
    #[inline]
    pub fn add(&self, a: i16, b: i16) -> i16 {
        self.narrow(a as i64 + b as i64)
    }

    /// Lane subtraction (`MVM_VEC_SUB` element step).
    #[inline]
    pub fn sub(&self, a: i16, b: i16) -> i16 {
        self.narrow(a as i64 - b as i64)
    }

    /// Lane multiply with Q.2F → Q.F rescale (`MVM_ELEM_MUTLI` element
    /// step). Floor rounding — see [`FixedSpec::rescale`].
    #[inline]
    pub fn mul(&self, a: i16, b: i16) -> i16 {
        self.rescale(a as i64 * b as i64)
    }

    // ---- vector ops (what one MVM does per instruction) ----

    /// Vector dot product: 48-bit accumulate of Q.2F products, then one
    /// rescale + narrow (`MVM_VEC_DOT`; floor rounding — see
    /// [`FixedSpec::rescale`]).
    pub fn dot(&self, a: &[i16], b: &[i16]) -> i16 {
        assert_eq!(a.len(), b.len(), "dot: length mismatch");
        self.rescale(self.dot_acc(a, b))
    }

    /// The raw 48-bit (i64) accumulator value of a dot product, before the
    /// rescale/narrow stage. Exposed for the cycle-accurate DSP model.
    #[inline]
    pub fn dot_acc(&self, a: &[i16], b: &[i16]) -> i64 {
        let mut acc: i64 = 0;
        for (&x, &y) in a.iter().zip(b.iter()) {
            acc += x as i64 * y as i64;
        }
        acc
    }

    /// Vector summation: 48-bit accumulate of Q.F lanes, narrow, no shift
    /// (`MVM_VEC_SUM`).
    pub fn sum(&self, a: &[i16]) -> i16 {
        let acc: i64 = a.iter().map(|&x| x as i64).sum();
        self.narrow(acc)
    }

    /// Element-wise vector addition (`VECTOR_ADDITION`).
    pub fn vadd(&self, a: &[i16], b: &[i16]) -> Vec<i16> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| self.add(x, y)).collect()
    }

    /// Element-wise vector subtraction (`VECTOR_SUBTRACTION`).
    pub fn vsub(&self, a: &[i16], b: &[i16]) -> Vec<i16> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| self.sub(x, y)).collect()
    }

    /// Element-wise vector multiplication (`ELEMENT_MULTIPLICATION`).
    pub fn vmul(&self, a: &[i16], b: &[i16]) -> Vec<i16> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| self.mul(x, y)).collect()
    }
}

impl Default for FixedSpec {
    fn default() -> Self {
        FixedSpec::PAPER
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn paper_spec_is_q8_7() {
        let s = FixedSpec::PAPER;
        assert_eq!(s.frac_bits, 7);
        assert_eq!(s.scale(), 128.0);
        assert_eq!(s.from_f64(1.0), 128);
        assert_eq!(s.to_f64(128), 1.0);
        assert_eq!(s.from_f64(-0.5), -64);
    }

    #[test]
    fn encode_decode_roundtrip_within_resolution() {
        let s = FixedSpec::q(7);
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = (r.gen_f64() - 0.5) * 400.0; // within Q8.7 range ±256
            let v = s.from_f64(x.clamp(-255.0, 255.0));
            let y = s.to_f64(v);
            assert!((x.clamp(-255.0, 255.0) - y).abs() <= s.resolution() * 0.5 + 1e-12);
        }
    }

    #[test]
    fn mul_rescales_q2f_to_qf() {
        let s = FixedSpec::q(7);
        // 1.5 * 2.0 = 3.0
        let a = s.from_f64(1.5);
        let b = s.from_f64(2.0);
        assert_eq!(s.to_f64(s.mul(a, b)), 3.0);
        // 0.0078125 * 0.0078125 underflows to 0 at Q.7 (truncation toward -inf)
        let tiny = s.from_f64(s.resolution());
        assert_eq!(s.mul(tiny, tiny), 0);
    }

    #[test]
    fn mul_truncates_toward_neg_infinity() {
        // Arithmetic shift right truncates toward -inf: (-1 * 1) in Q.7 is
        // -(2^-7 * 2^-7) = -2^-14, which shifts to -1, not 0.
        let s = FixedSpec::q(7);
        assert_eq!(s.mul(-1, 1), -1);
        assert_eq!(s.mul(1, 1), 0);
    }

    #[test]
    fn rescale_is_the_shared_floor_rule() {
        let s = FixedSpec::q(7);
        let mut r = Rng::new(0xF10);
        for _ in 0..2000 {
            let (a, b) = (r.gen_i16(), r.gen_i16());
            let wide = a as i64 * b as i64;
            // mul is exactly rescale, and rescale is floor division
            assert_eq!(s.mul(a, b), s.rescale(wide));
            assert_eq!(s.rescale(wide), s.narrow(wide.div_euclid(1 << s.frac_bits)));
        }
        // the documented floor bias: -(2^-14) floors to -1 ULP, not 0
        assert_eq!(s.rescale(-1), -1);
        assert_eq!(s.rescale(1), 0);
    }

    #[test]
    fn wrap_vs_saturate() {
        let w = FixedSpec::q(7);
        let st = w.saturating();
        // 200.0 * 200.0 = 40000 >> Q8.7 range.
        let a = w.from_f64(200.0);
        let wide = (a as i64 * a as i64) >> 7;
        assert_eq!(w.mul(a, a), wide as i16); // wraps
        assert_eq!(st.mul(a, a), i16::MAX); // clamps
        // add overflow
        assert_eq!(w.add(i16::MAX, 1), i16::MIN);
        assert_eq!(st.add(i16::MAX, 1), i16::MAX);
    }

    #[test]
    fn dot_matches_scalar_decomposition_when_exact() {
        let s = FixedSpec::q(7);
        let a = s.encode_vec(&[1.0, 2.0, -3.0, 0.5]);
        let b = s.encode_vec(&[4.0, -1.0, 2.0, 8.0]);
        // 4 - 2 - 6 + 4 = 0
        assert_eq!(s.dot(&a, &b), 0);
    }

    #[test]
    fn dot_accumulates_before_single_rescale() {
        // Accumulating in Q.2F then one shift differs from per-product
        // shifts: two products of 0.5-resolution magnitudes must not each
        // lose their fraction. dot([tiny,tiny],[tiny,tiny]) where
        // tiny^2 = 2^-14: sum = 2*2^-14 = 2^-13, >>7 → 0 (still below
        // resolution) but acc is 2, not 0.
        let s = FixedSpec::q(7);
        assert_eq!(s.dot_acc(&[1, 1], &[1, 1]), 2);
        assert_eq!(s.dot(&[1, 1], &[1, 1]), 0);
        // 64 lanes of 1*1 = 64 ≥ 128? no → still 0; 128 lanes → 1.
        let ones = vec![1i16; 128];
        assert_eq!(s.dot(&ones, &ones), 1);
    }

    #[test]
    fn sum_has_no_shift() {
        let s = FixedSpec::q(7);
        let v = s.encode_vec(&[1.0, 2.0, 3.0]);
        assert_eq!(s.to_f64(s.sum(&v)), 6.0);
    }

    #[test]
    fn vector_ops_match_lane_ops() {
        let s = FixedSpec::q(7);
        let mut r = Rng::new(5);
        let a: Vec<i16> = (0..256).map(|_| r.gen_i16()).collect();
        let b: Vec<i16> = (0..256).map(|_| r.gen_i16()).collect();
        let add = s.vadd(&a, &b);
        let sub = s.vsub(&a, &b);
        let mul = s.vmul(&a, &b);
        for i in 0..a.len() {
            assert_eq!(add[i], s.add(a[i], b[i]));
            assert_eq!(sub[i], s.sub(a[i], b[i]));
            assert_eq!(mul[i], s.mul(a[i], b[i]));
        }
    }

    #[test]
    fn dot_never_overflows_i48_at_paper_sizes() {
        // Worst case |a_i * b_i| = 2^30; 1024 lanes → 2^40 < 2^47.
        let s = FixedSpec::q(7);
        let a = vec![i16::MIN; 1024];
        let acc = s.dot_acc(&a, &a);
        assert_eq!(acc, (i16::MIN as i64) * (i16::MIN as i64) * 1024);
        assert!(acc < (1i64 << 47));
    }

    #[test]
    fn narrow_wrap_is_low_16_bits() {
        let s = FixedSpec::q(7);
        assert_eq!(s.narrow(0x1_0000), 0);
        assert_eq!(s.narrow(0x1_8000), i16::MIN);
        assert_eq!(s.narrow(-1), -1);
    }
}
