//! Micro-benchmark harness — a small criterion substitute (criterion is not
//! in the sandbox's vendored crate set; see DESIGN.md §2).
//!
//! Usage from a `[[bench]] harness = false` binary:
//!
//! ```no_run
//! use mfnn::bench::{Bencher, Suite};
//! let mut suite = Suite::new("group_perf");
//! suite.bench("vec_add_1024", |b: &mut Bencher| {
//!     let xs = vec![1i16; 1024];
//!     b.iter_with_elements(1024, || xs.iter().map(|&x| x as i64).sum::<i64>());
//! });
//! suite.finish();
//! ```
//!
//! Each benchmark runs a warmup phase then collects wall-clock samples and
//! reports mean / median / p95 / min plus element throughput when the
//! workload declares its element count.

use crate::report::Table;
use std::time::{Duration, Instant};

/// Collected statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark name.
    pub name: String,
    /// Number of measured samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: u64,
    /// Mean time per iteration (ns).
    pub mean_ns: f64,
    /// Median time per iteration (ns).
    pub median_ns: f64,
    /// 95th percentile time per iteration (ns).
    pub p95_ns: f64,
    /// Fastest sample per-iteration time (ns).
    pub min_ns: f64,
    /// Elements processed per iteration (0 = not declared).
    pub elements: u64,
}

impl Stats {
    /// Element throughput in elements/second (None unless declared).
    pub fn throughput(&self) -> Option<f64> {
        if self.elements == 0 || self.median_ns == 0.0 {
            None
        } else {
            Some(self.elements as f64 / (self.median_ns * 1e-9))
        }
    }
}

/// Passed to each benchmark closure; call one of the `iter*` methods once.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    result: Option<(u64, Vec<Duration>, u64)>, // (iters/sample, samples, elements)
}

impl Bencher {
    fn new(warmup: Duration, measure: Duration, max_samples: usize) -> Bencher {
        Bencher { warmup, measure, max_samples, result: None }
    }

    /// Measure `f`, which is treated as processing `elements` items per call.
    pub fn iter_with_elements<T, F: FnMut() -> T>(&mut self, elements: u64, mut f: F) {
        // Warmup + calibration: find iters/sample so one sample ≈ 1–10 ms.
        let warm_end = Instant::now() + self.warmup;
        let mut calib_iters: u64 = 0;
        let calib_start = Instant::now();
        loop {
            std::hint::black_box(f());
            calib_iters += 1;
            if Instant::now() >= warm_end {
                break;
            }
        }
        let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters as f64;
        let target_sample = 2e-3; // 2 ms per sample
        let iters_per_sample = ((target_sample / per_iter.max(1e-9)) as u64).clamp(1, 1 << 24);

        let mut samples = Vec::new();
        let measure_end = Instant::now() + self.measure;
        while samples.len() < self.max_samples
            && (samples.len() < 8 || Instant::now() < measure_end)
        {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed());
            if samples.len() >= 8 && Instant::now() >= measure_end {
                break;
            }
        }
        self.result = Some((iters_per_sample, samples, elements));
    }

    /// Measure `f` with no element-count (latency only).
    pub fn iter<T, F: FnMut() -> T>(&mut self, f: F) {
        self.iter_with_elements(0, f)
    }
}

/// A named collection of benchmarks that prints a table at the end.
pub struct Suite {
    name: String,
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    results: Vec<Stats>,
    notes: Vec<(String, String)>,
    quick: bool,
}

impl Suite {
    /// New suite with default timing (0.3 s warmup, 1 s measure, 64 samples).
    /// Set env `MFNN_BENCH_QUICK=1` for a fast smoke run (CI / tests).
    pub fn new(name: &str) -> Suite {
        let quick = std::env::var("MFNN_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        let (warmup, measure) = if quick {
            (Duration::from_millis(20), Duration::from_millis(60))
        } else {
            (Duration::from_millis(300), Duration::from_secs(1))
        };
        Suite {
            name: name.to_string(),
            warmup,
            measure,
            max_samples: 64,
            results: Vec::new(),
            notes: Vec::new(),
            quick,
        }
    }

    /// Is this a quick (smoke) run?
    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Attach a named note to the suite's JSON (`"notes": {…}`) —
    /// deterministic, wall-clock-independent numbers a suite wants to
    /// record alongside its timings (the serving bench stores simulated
    /// cycle throughput and speedups here, so the perf trajectory is
    /// comparable across machines).
    pub fn note(&mut self, key: &str, value: impl std::fmt::Display) {
        let value = value.to_string();
        eprintln!("  note: {key} = {value}");
        self.notes.push((key.to_string(), value));
    }

    /// Run one benchmark.
    pub fn bench<F: FnOnce(&mut Bencher)>(&mut self, name: &str, f: F) -> &Stats {
        let mut b = Bencher::new(self.warmup, self.measure, self.max_samples);
        f(&mut b);
        let (iters, samples, elements) =
            b.result.expect("benchmark closure must call one of Bencher::iter*");
        let mut per_iter_ns: Vec<f64> =
            samples.iter().map(|d| d.as_secs_f64() * 1e9 / iters as f64).collect();
        per_iter_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = per_iter_ns.len();
        let stats = Stats {
            name: name.to_string(),
            samples: n,
            iters_per_sample: iters,
            mean_ns: per_iter_ns.iter().sum::<f64>() / n as f64,
            median_ns: per_iter_ns[n / 2],
            p95_ns: per_iter_ns[(n * 95 / 100).min(n - 1)],
            min_ns: per_iter_ns[0],
            elements,
        };
        eprintln!(
            "  {:<40} median {:>12} p95 {:>12}{}",
            name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.p95_ns),
            stats
                .throughput()
                .map(|t| format!("  {:>12}/s", fmt_count(t)))
                .unwrap_or_default()
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All collected stats.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Directory `BENCH_<suite>.json` files are written to: the repo
    /// root (one level above the crate), overridable with
    /// `MFNN_BENCH_DIR`.
    pub fn json_dir() -> std::path::PathBuf {
        if let Ok(d) = std::env::var("MFNN_BENCH_DIR") {
            return std::path::PathBuf::from(d);
        }
        // The baked-in path only exists on the build machine; relocated
        // binaries fall back to the working directory.
        let baked = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."));
        if baked.is_dir() {
            baked
        } else {
            std::path::PathBuf::from(".")
        }
    }

    /// Serialise the collected stats as JSON (median/mean/p95/min ns and
    /// element throughput per benchmark) so the perf trajectory can be
    /// tracked across PRs.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"suite\": {},\n", json_str(&self.name)));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        if !self.notes.is_empty() {
            s.push_str("  \"notes\": {\n");
            for (i, (k, v)) in self.notes.iter().enumerate() {
                s.push_str(&format!(
                    "    {}: {}{}\n",
                    json_str(k),
                    json_str(v),
                    if i + 1 == self.notes.len() { "" } else { "," },
                ));
            }
            s.push_str("  },\n");
        }
        s.push_str("  \"benchmarks\": [\n");
        for (i, b) in self.results.iter().enumerate() {
            let tp = b
                .throughput()
                .map(|t| format!("{t:.3}"))
                .unwrap_or_else(|| "null".into());
            s.push_str(&format!(
                "    {{\"name\": {}, \"samples\": {}, \"iters_per_sample\": {}, \
                 \"median_ns\": {:.3}, \"mean_ns\": {:.3}, \"p95_ns\": {:.3}, \
                 \"min_ns\": {:.3}, \"elements\": {}, \"throughput_per_sec\": {}}}{}\n",
                json_str(&b.name),
                b.samples,
                b.iters_per_sample,
                b.median_ns,
                b.mean_ns,
                b.p95_ns,
                b.min_ns,
                b.elements,
                tp,
                if i + 1 == self.results.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Print the summary table and write `BENCH_<suite>.json` into
    /// [`Suite::json_dir`]; returns the table for further use.
    pub fn finish(&self) -> Table {
        let path = Suite::json_dir().join(format!("BENCH_{}.json", self.name));
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => eprintln!("  wrote {}", path.display()),
            Err(e) => eprintln!("  warning: could not write {}: {e}", path.display()),
        }
        self.finish_table()
    }

    /// Print the summary table only (no JSON side effect).
    pub fn finish_table(&self) -> Table {
        let mut t = Table::new(vec!["benchmark", "median", "mean", "p95", "min", "throughput"])
            .with_title(format!("bench: {}", self.name))
            .numeric();
        for s in &self.results {
            t.row(vec![
                s.name.clone(),
                fmt_ns(s.median_ns),
                fmt_ns(s.mean_ns),
                fmt_ns(s.p95_ns),
                fmt_ns(s.min_ns),
                s.throughput().map(|x| format!("{}/s", fmt_count(x))).unwrap_or_else(|| "-".into()),
            ]);
        }
        println!("{}", t.render());
        t
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// shared with the serving metrics JSON writer.
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Human-format a nanosecond duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Human-format a large count (K/M/G).
pub fn fmt_count(x: f64) -> String {
    if x < 1e3 {
        format!("{x:.0}")
    } else if x < 1e6 {
        format!("{:.1}K", x / 1e3)
    } else if x < 1e9 {
        format!("{:.1}M", x / 1e6)
    } else {
        format!("{:.2}G", x / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_samples() {
        std::env::set_var("MFNN_BENCH_QUICK", "1");
        std::env::set_var("MFNN_BENCH_DIR", std::env::temp_dir());
        let mut suite = Suite::new("selftest");
        let s = suite.bench("noop_sum", |b| {
            let xs: Vec<u64> = (0..64).collect();
            b.iter_with_elements(64, || xs.iter().sum::<u64>())
        });
        assert!(s.samples >= 8);
        assert!(s.median_ns > 0.0);
        assert!(s.throughput().unwrap() > 0.0);
        let t = suite.finish();
        assert_eq!(t.len(), 1);
        // the JSON sidecar landed next to the suite and parses the
        // fields the CI trend tooling reads
        let json = std::fs::read_to_string(Suite::json_dir().join("BENCH_selftest.json")).unwrap();
        assert!(json.contains("\"suite\": \"selftest\""), "{json}");
        assert!(json.contains("\"name\": \"noop_sum\""), "{json}");
        assert!(json.contains("\"median_ns\""), "{json}");
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("tab\there"), "\"tab\\there\"");
    }

    #[test]
    fn notes_land_in_the_suite_json() {
        let mut suite = Suite::new("notetest");
        suite.note("sim_rps", format!("{:.2}", 1234.5));
        suite.note("speedup", "3.1");
        let json = suite.to_json();
        assert!(json.contains("\"notes\": {"), "{json}");
        assert!(json.contains("\"sim_rps\": \"1234.50\","), "{json}");
        assert!(json.contains("\"speedup\": \"3.1\""), "{json}");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(5.0), "5.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_count(999.0), "999");
        assert_eq!(fmt_count(2.5e6), "2.5M");
        assert_eq!(fmt_count(3.95e8), "395.0M");
    }
}
