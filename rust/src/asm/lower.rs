//! Semantic analysis + lowering of parsed assembly to vector programs.
//!
//! Reconstructs an [`MlpSpec`] from each `NET` block's `MLP` chain,
//! validates shapes/references, then reuses the training/inference
//! lowering of [`crate::nn::lowering`] and renames the generated buffers
//! back to the user's assembly-level names.

use super::ast::{AsmNet, Directive};
use super::parser::{parse, ParseError};
use crate::assembler::program::BufKind;
use crate::fixed::FixedSpec;
use crate::nn::graph::{lower_mlp_forward, lower_mlp_train};
use crate::nn::lowering::{LowerError, LoweredMlp};
use crate::nn::lut::{ActKind, AddrMode};
use crate::nn::mlp::{LayerSpec, LutParams, MlpSpec};
use thiserror::Error;

/// Lowering / semantic errors.
#[derive(Debug, Error, PartialEq)]
pub enum AsmError {
    /// Parse failure.
    #[error(transparent)]
    Parse(#[from] ParseError),
    /// Program-construction failure.
    #[error("net {0}: {1}")]
    Lower(String, LowerError),
    /// Reference to an undefined name.
    #[error("line {0}: {1} {2:?} is not defined")]
    Undefined(usize, &'static str, String),
    /// Shape mismatch between chained layers / declarations.
    #[error("line {0}: {1}")]
    Shape(usize, String),
    /// Structural issues (missing INPUT/OUTPUT/MLP, duplicate names...).
    #[error("net {0}: {1}")]
    Structure(String, String),
    /// ACT options differ between layers of one net (one ACTPRO generic
    /// set per machine).
    #[error("line {0}: ACT options conflict with an earlier ACT in this net")]
    LutConflict(usize),
}

/// A lowered net, with the mapping from assembly names to program buffers.
#[derive(Debug, Clone)]
pub struct LoweredNet {
    /// The reconstructed spec.
    pub spec: MlpSpec,
    /// The lowered program + handles (train program when `TRAIN` present).
    pub mlp: LoweredMlp,
    /// Was this a training net?
    pub train: bool,
    /// Learning rate of the `TRAIN` directive (training nets only).
    pub lr: Option<f64>,
    /// Batch size (INPUT rows).
    pub batch: usize,
}

/// Parse + lower a whole source file (one program per `NET`).
pub fn lower_file(text: &str) -> Result<Vec<LoweredNet>, AsmError> {
    let file = parse(text)?;
    file.nets.iter().map(lower_net).collect()
}

/// Lower one `NET` block.
pub fn lower_net(net: &AsmNet) -> Result<LoweredNet, AsmError> {
    // Symbol tables.
    struct Mat {
        rows: usize,
        cols: usize,
    }
    let mut inputs: Vec<(String, Mat)> = Vec::new();
    let mut weights: Vec<(String, Mat)> = Vec::new();
    let mut biases: Vec<(String, usize)> = Vec::new();
    let mut acts: Vec<(String, ActKind, Option<u32>, Option<AddrMode>, Option<bool>)> = Vec::new();
    let mut mlps: Vec<(usize, String, String, String, String, String)> = Vec::new();
    let mut outputs: Vec<(usize, String)> = Vec::new();
    let mut target: Option<(usize, String, Mat)> = None;
    let mut train: Option<(usize, f64)> = None;
    let mut fixed = FixedSpec::PAPER;

    for item in &net.items {
        match &item.dir {
            Directive::Net { .. } => unreachable!("parser strips NET"),
            Directive::Fixed { frac_bits, saturate } => {
                fixed = FixedSpec::q(*frac_bits);
                if *saturate {
                    fixed = fixed.saturating();
                }
            }
            Directive::Input { name, rows, cols } => {
                inputs.push((name.clone(), Mat { rows: *rows, cols: *cols }))
            }
            Directive::Weight { name, rows, cols } => {
                weights.push((name.clone(), Mat { rows: *rows, cols: *cols }))
            }
            Directive::Bias { name, size } => biases.push((name.clone(), *size)),
            Directive::Act { name, kind, shift, mode, interp } => {
                acts.push((name.clone(), *kind, *shift, *mode, *interp))
            }
            Directive::Mlp { out, input, weight, bias, act } => mlps.push((
                item.line,
                out.clone(),
                input.clone(),
                weight.clone(),
                bias.clone(),
                act.clone(),
            )),
            Directive::Output { name } => outputs.push((item.line, name.clone())),
            Directive::Target { name, rows, cols } => {
                target = Some((item.line, name.clone(), Mat { rows: *rows, cols: *cols }))
            }
            Directive::Train { lr } => train = Some((item.line, *lr)),
        }
    }

    let err_structure =
        |msg: String| -> AsmError { AsmError::Structure(net.name.clone(), msg) };
    if inputs.len() != 1 {
        return Err(err_structure(format!("expected exactly 1 INPUT, found {}", inputs.len())));
    }
    if mlps.is_empty() {
        return Err(err_structure("no MLP layers".into()));
    }
    if outputs.len() != 1 {
        return Err(err_structure(format!("expected exactly 1 OUTPUT, found {}", outputs.len())));
    }
    let (input_name, input_mat) = (&inputs[0].0, &inputs[0].1);
    let batch = input_mat.rows;

    // Walk the MLP chain, checking shapes and reconstructing layers.
    let mut layers: Vec<LayerSpec> = Vec::new();
    let mut w_names = Vec::new();
    let mut b_names = Vec::new();
    let mut prev_out_name = input_name.clone();
    let mut prev_width = input_mat.cols;
    let mut lut: Option<LutParams> = None;
    for (line, out, inp, wname, bname, aname) in &mlps {
        if inp != &prev_out_name {
            return Err(AsmError::Shape(
                *line,
                format!(
                    "MLP input {inp:?} must chain from the previous output {prev_out_name:?}"
                ),
            ));
        }
        let w = weights
            .iter()
            .find(|(n, _)| n == wname)
            .ok_or_else(|| AsmError::Undefined(*line, "weight", wname.clone()))?;
        let b = biases
            .iter()
            .find(|(n, _)| n == bname)
            .ok_or_else(|| AsmError::Undefined(*line, "bias", bname.clone()))?;
        let a = acts
            .iter()
            .find(|(n, ..)| n == aname)
            .ok_or_else(|| AsmError::Undefined(*line, "activation", aname.clone()))?;
        if w.1.rows != prev_width {
            return Err(AsmError::Shape(
                *line,
                format!("weight {wname:?} has {} rows, layer input is {prev_width}", w.1.rows),
            ));
        }
        if b.1 != w.1.cols {
            return Err(AsmError::Shape(
                *line,
                format!("bias {bname:?} size {} != weight cols {}", b.1, w.1.cols),
            ));
        }
        // One ACTPRO generic set per machine: all ACTs must agree.
        let this_lut = LutParams {
            shift: a.2.unwrap_or(fixed.frac_bits),
            mode: a.3.unwrap_or(AddrMode::Wrap),
            interp: a.4.unwrap_or(false),
        };
        match &lut {
            None => lut = Some(this_lut),
            Some(prev) if *prev == this_lut => {}
            Some(_) => return Err(AsmError::LutConflict(*line)),
        }
        layers.push(LayerSpec { inputs: w.1.rows, outputs: w.1.cols, act: a.1 });
        w_names.push(wname.clone());
        b_names.push(bname.clone());
        prev_out_name = out.clone();
        prev_width = w.1.cols;
    }
    let (out_line, out_name) = &outputs[0];
    if out_name != &prev_out_name {
        return Err(AsmError::Shape(
            *out_line,
            format!("OUTPUT {out_name:?} is not the final MLP output {prev_out_name:?}"),
        ));
    }

    let spec = MlpSpec {
        name: net.name.clone(),
        layers,
        fixed,
        lut: lut.unwrap_or(LutParams::PAPER),
    };
    spec.check().map_err(|e| AsmError::Lower(net.name.clone(), LowerError::Spec(e)))?;

    // Training nets need TARGET shape (batch × out_dim).
    let mut mlp = if let Some((tline, tlr)) = train {
        let (yline, yname, ymat) = target
            .as_ref()
            .ok_or_else(|| AsmError::Shape(tline, "TRAIN requires a TARGET".into()))?;
        if ymat.rows != batch || ymat.cols != spec.output_dim() {
            return Err(AsmError::Shape(
                *yline,
                format!(
                    "TARGET {yname:?} is {}x{}, expected {batch}x{}",
                    ymat.rows,
                    ymat.cols,
                    spec.output_dim()
                ),
            ));
        }
        lower_mlp_train(&spec, batch, tlr)
            .map_err(|e| AsmError::Lower(net.name.clone(), e))?
    } else {
        lower_mlp_forward(&spec, batch).map_err(|e| AsmError::Lower(net.name.clone(), e))?
    };

    // Rename generated buffers to assembly names.
    rename(&mut mlp, "x", input_name);
    for (l, wn) in w_names.iter().enumerate() {
        rename(&mut mlp, &format!("w{l}"), wn);
        rename(&mut mlp, &format!("b{l}"), &b_names[l]);
    }
    let last = spec.layers.len() - 1;
    rename(&mut mlp, &format!("o{last}"), out_name);
    if let Some((_, yname, _)) = &target {
        if train.is_some() {
            rename(&mut mlp, "y", yname);
        }
    }
    // intermediate MLP outputs get the user's names too
    for (l, (_, out, ..)) in mlps.iter().enumerate().take(mlps.len() - 1) {
        rename(&mut mlp, &format!("o{l}"), out);
    }

    debug_assert!(mlp
        .program
        .buffers
        .iter()
        .any(|b| b.name == *out_name && matches!(b.kind, BufKind::Output)));
    Ok(LoweredNet { spec, train: train.is_some(), lr: train.map(|(_, lr)| lr), batch, mlp })
}

fn rename(mlp: &mut LoweredMlp, from: &str, to: &str) {
    if from == to {
        return;
    }
    if let Some(id) = mlp.program.buffer_named(from) {
        mlp.program.buffers[id].name = to.to_string();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{FpgaDevice, MatrixMachine};
    use crate::util::Rng;

    const FWD: &str = "
NET fwd
FIXED 10 saturate
INPUT img 8 15
WEIGHT w0 15 16
BIAS b0 16
ACT a0 relu shift=5 mode=clamp interp=1
MLP h img w0 b0 a0
WEIGHT w1 16 10
BIAS b1 10
ACT a1 identity shift=5 mode=clamp interp=1
MLP scores h w1 b1 a1
OUTPUT scores
";

    #[test]
    fn lowers_forward_net() {
        let nets = lower_file(FWD).unwrap();
        assert_eq!(nets.len(), 1);
        let n = &nets[0];
        assert!(!n.train);
        assert_eq!(n.batch, 8);
        assert_eq!(n.spec.layers.len(), 2);
        // user names survive
        let p = &n.mlp.program;
        for name in ["img", "w0", "b0", "w1", "b1", "scores", "h"] {
            assert!(p.buffer_named(name).is_some(), "missing {name}");
        }
        p.check().unwrap();
    }

    #[test]
    fn lowered_net_runs_on_machine() {
        let nets = lower_file(FWD).unwrap();
        let p = &nets[0].mlp.program;
        let mut m = MatrixMachine::new(FpgaDevice::selected(), p).unwrap();
        let mut r = Rng::new(1);
        let f = nets[0].spec.fixed;
        let q = |n: usize, r: &mut Rng| -> Vec<i16> {
            (0..n).map(|_| f.from_f64(r.gen_f64() - 0.5)).collect()
        };
        m.bind_named("img", &q(8 * 15, &mut r)).unwrap();
        m.bind_named("w0", &q(15 * 16, &mut r)).unwrap();
        m.bind_named("b0", &q(16, &mut r)).unwrap();
        m.bind_named("w1", &q(16 * 10, &mut r)).unwrap();
        m.bind_named("b1", &q(10, &mut r)).unwrap();
        m.execute();
        assert_eq!(m.read_named("scores").unwrap().len(), 80);
    }

    #[test]
    fn train_net_has_loss_and_target() {
        let src = format!(
            "{FWD}TARGET labels 8 10\nTRAIN lr=0.00390625\n"
        );
        let nets = lower_file(&src).unwrap();
        let n = &nets[0];
        assert!(n.train);
        assert!(n.mlp.loss.is_some());
        assert!(n.mlp.program.buffer_named("labels").is_some());
    }

    #[test]
    fn chain_errors() {
        let bad = "
NET b
INPUT x 4 2
WEIGHT w0 3 4
BIAS b0 4
ACT a0 relu
MLP h x w0 b0 a0
OUTPUT h
";
        assert!(matches!(lower_file(bad), Err(AsmError::Shape(_, _))));

        let bad2 = "
NET b
INPUT x 4 2
WEIGHT w0 2 4
BIAS b0 5
ACT a0 relu
MLP h x w0 b0 a0
OUTPUT h
";
        assert!(matches!(lower_file(bad2), Err(AsmError::Shape(_, _))));

        let undef = "
NET b
INPUT x 4 2
BIAS b0 4
ACT a0 relu
MLP h x nothere b0 a0
OUTPUT h
";
        assert!(matches!(lower_file(undef), Err(AsmError::Undefined(_, "weight", _))));
    }

    #[test]
    fn conflicting_act_options_rejected() {
        let bad = "
NET c
INPUT x 2 2
WEIGHT w0 2 2
BIAS b0 2
ACT a0 relu shift=5
MLP h x w0 b0 a0
WEIGHT w1 2 2
BIAS b1 2
ACT a1 relu shift=3
MLP o h w1 b1 a1
OUTPUT o
";
        assert!(matches!(lower_file(bad), Err(AsmError::LutConflict(_))));
    }

    #[test]
    fn structural_errors() {
        assert!(matches!(
            lower_file("NET n\nINPUT a 1 1\nOUTPUT a"),
            Err(AsmError::Structure(_, _))
        ));
        let two_inputs = "
NET n
INPUT a 1 1
INPUT b 1 1
WEIGHT w 1 1
BIAS c 1
ACT k relu
MLP o a w c k
OUTPUT o
";
        assert!(matches!(lower_file(two_inputs), Err(AsmError::Structure(_, _))));
    }

    #[test]
    fn train_without_target_rejected() {
        let src = format!("{FWD}TRAIN lr=0.01\n");
        assert!(matches!(lower_file(&src), Err(AsmError::Shape(_, _))));
    }
}
