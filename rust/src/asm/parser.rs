//! Parser for the neural-network assembly text format.
//!
//! Line-oriented: `;` or `#` start comments, blank lines are skipped,
//! tokens are whitespace-separated, directives are case-insensitive,
//! options are `key=value` pairs.

use super::ast::{AsmFile, AsmNet, Directive, Item};
use crate::nn::lut::{ActKind, AddrMode};
use thiserror::Error;

/// Parse errors with 1-based line numbers.
#[derive(Debug, Error, PartialEq)]
pub enum ParseError {
    /// Unknown directive word.
    #[error("line {0}: unknown directive {1:?}")]
    UnknownDirective(usize, String),
    /// Wrong argument count or malformed argument.
    #[error("line {0}: {1}")]
    BadArgs(usize, String),
    /// Directive before any `NET`.
    #[error("line {0}: directive outside a NET block")]
    OutsideNet(usize),
    /// Empty file / no NET blocks.
    #[error("no NET blocks found")]
    Empty,
}

fn ident(line: usize, tok: &str) -> Result<String, ParseError> {
    let ok = !tok.is_empty()
        && tok.chars().next().unwrap().is_ascii_alphabetic()
        && tok.chars().all(|c| c.is_ascii_alphanumeric() || c == '_');
    if ok {
        Ok(tok.to_string())
    } else {
        Err(ParseError::BadArgs(line, format!("bad identifier {tok:?}")))
    }
}

fn num<T: std::str::FromStr>(line: usize, tok: &str, what: &str) -> Result<T, ParseError> {
    tok.parse::<T>()
        .map_err(|_| ParseError::BadArgs(line, format!("cannot parse {what} from {tok:?}")))
}

/// Parse one source file.
pub fn parse(text: &str) -> Result<AsmFile, ParseError> {
    let mut file = AsmFile::default();
    let mut current: Option<AsmNet> = None;
    for (idx, raw) in text.lines().enumerate() {
        let line = idx + 1;
        let body = raw.split([';', '#']).next().unwrap_or("").trim();
        if body.is_empty() {
            continue;
        }
        let toks: Vec<&str> = body.split_whitespace().collect();
        let dir_word = toks[0].to_ascii_uppercase();
        let args = &toks[1..];
        let dir = match dir_word.as_str() {
            "NET" => {
                if args.len() != 1 {
                    return Err(ParseError::BadArgs(line, "NET takes one name".into()));
                }
                if let Some(net) = current.take() {
                    file.nets.push(net);
                }
                current = Some(AsmNet { name: ident(line, args[0])?, items: Vec::new() });
                continue;
            }
            "FIXED" => {
                if args.len() != 2 {
                    return Err(ParseError::BadArgs(
                        line,
                        "FIXED takes <frac_bits> <wrap|saturate>".into(),
                    ));
                }
                let frac: u32 = num(line, args[0], "frac_bits")?;
                if frac >= 16 {
                    return Err(ParseError::BadArgs(line, format!("frac_bits {frac} must be < 16")));
                }
                let saturate = match args[1] {
                    "wrap" => false,
                    "saturate" => true,
                    other => {
                        return Err(ParseError::BadArgs(line, format!("bad mode {other:?}")))
                    }
                };
                Directive::Fixed { frac_bits: frac, saturate }
            }
            "INPUT" | "TARGET" | "WEIGHT" => {
                if args.len() != 3 {
                    return Err(ParseError::BadArgs(
                        line,
                        format!("{dir_word} takes <name> <N> <M>"),
                    ));
                }
                let name = ident(line, args[0])?;
                let rows = num(line, args[1], "N")?;
                let cols = num(line, args[2], "M")?;
                match dir_word.as_str() {
                    "INPUT" => Directive::Input { name, rows, cols },
                    "TARGET" => Directive::Target { name, rows, cols },
                    _ => Directive::Weight { name, rows, cols },
                }
            }
            "BIAS" => {
                if args.len() != 2 {
                    return Err(ParseError::BadArgs(line, "BIAS takes <name> <N>".into()));
                }
                Directive::Bias { name: ident(line, args[0])?, size: num(line, args[1], "N")? }
            }
            "ACT" => {
                if args.len() < 2 {
                    return Err(ParseError::BadArgs(line, "ACT takes <name> <kind> [opts]".into()));
                }
                let name = ident(line, args[0])?;
                let kind = ActKind::parse(args[1]).ok_or_else(|| {
                    ParseError::BadArgs(line, format!("unknown activation {:?}", args[1]))
                })?;
                let (mut shift, mut mode, mut interp) = (None, None, None);
                for opt in &args[2..] {
                    let (k, v) = opt.split_once('=').ok_or_else(|| {
                        ParseError::BadArgs(line, format!("bad option {opt:?} (want key=value)"))
                    })?;
                    match k {
                        "shift" => shift = Some(num(line, v, "shift")?),
                        "mode" => {
                            mode = Some(match v {
                                "wrap" => AddrMode::Wrap,
                                "clamp" => AddrMode::Clamp,
                                _ => {
                                    return Err(ParseError::BadArgs(
                                        line,
                                        format!("bad mode {v:?}"),
                                    ))
                                }
                            })
                        }
                        "interp" => interp = Some(v == "1" || v == "true"),
                        _ => {
                            return Err(ParseError::BadArgs(line, format!("unknown option {k:?}")))
                        }
                    }
                }
                Directive::Act { name, kind, shift, mode, interp }
            }
            "MLP" => {
                if args.len() != 5 {
                    return Err(ParseError::BadArgs(
                        line,
                        "MLP takes <out> <in> <weight> <bias> <act>".into(),
                    ));
                }
                Directive::Mlp {
                    out: ident(line, args[0])?,
                    input: ident(line, args[1])?,
                    weight: ident(line, args[2])?,
                    bias: ident(line, args[3])?,
                    act: ident(line, args[4])?,
                }
            }
            "OUTPUT" => {
                if args.len() != 1 {
                    return Err(ParseError::BadArgs(line, "OUTPUT takes <name>".into()));
                }
                Directive::Output { name: ident(line, args[0])? }
            }
            "TRAIN" => {
                let mut lr = None;
                for opt in args {
                    if let Some(v) = opt.strip_prefix("lr=") {
                        lr = Some(num::<f64>(line, v, "lr")?);
                    } else {
                        return Err(ParseError::BadArgs(line, format!("unknown option {opt:?}")));
                    }
                }
                let lr =
                    lr.ok_or_else(|| ParseError::BadArgs(line, "TRAIN requires lr=<f>".into()))?;
                Directive::Train { lr }
            }
            other => return Err(ParseError::UnknownDirective(line, other.to_string())),
        };
        match current.as_mut() {
            Some(net) => net.items.push(Item { line, dir }),
            None => return Err(ParseError::OutsideNet(line)),
        }
    }
    if let Some(net) = current.take() {
        file.nets.push(net);
    }
    if file.nets.is_empty() {
        return Err(ParseError::Empty);
    }
    Ok(file)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
; a 2-layer classifier
NET demo
FIXED 10 saturate
INPUT x 16 4        ; batch 16, dim 4
WEIGHT w0 4 8
BIAS b0 8
ACT relu0 relu shift=5 mode=clamp interp=1
MLP h0 x w0 b0 relu0
WEIGHT w1 8 3
BIAS b1 3
ACT id1 identity shift=5 mode=clamp interp=1
MLP out h0 w1 b1 id1
OUTPUT out
TARGET y 16 3
TRAIN lr=0.00390625
"#;

    #[test]
    fn parses_full_net() {
        let f = parse(SAMPLE).unwrap();
        assert_eq!(f.nets.len(), 1);
        let net = &f.nets[0];
        assert_eq!(net.name, "demo");
        assert_eq!(net.items.len(), 13);
        assert!(matches!(net.items[0].dir, Directive::Fixed { frac_bits: 10, saturate: true }));
        assert!(matches!(
            net.items[1].dir,
            Directive::Input { rows: 16, cols: 4, .. }
        ));
        assert!(matches!(
            net.items.last().unwrap().dir,
            Directive::Train { lr } if lr == 0.00390625
        ));
    }

    #[test]
    fn comments_and_case_insensitivity() {
        let f = parse("net a\ninput x 2 2 # trailing\n  OutPut x").unwrap();
        assert_eq!(f.nets[0].items.len(), 2);
    }

    #[test]
    fn multiple_nets() {
        let f = parse("NET a\nINPUT x 1 1\nOUTPUT x\nNET b\nINPUT z 2 2\nOUTPUT z").unwrap();
        assert_eq!(f.nets.len(), 2);
        assert_eq!(f.nets[1].name, "b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        assert_eq!(
            parse("NET a\nBOGUS x"),
            Err(ParseError::UnknownDirective(2, "BOGUS".into()))
        );
        assert_eq!(parse("INPUT x 1 1"), Err(ParseError::OutsideNet(1)));
        assert_eq!(parse(""), Err(ParseError::Empty));
        assert!(matches!(parse("NET a\nINPUT x one 1"), Err(ParseError::BadArgs(2, _))));
        assert!(matches!(parse("NET a\nACT t swish"), Err(ParseError::BadArgs(2, _))));
        assert!(matches!(parse("NET a\nTRAIN"), Err(ParseError::BadArgs(2, _))));
        assert!(matches!(parse("NET a\nFIXED 16 wrap"), Err(ParseError::BadArgs(2, _))));
    }
}
