//! Neural-network **assembly language** (paper §3.1, Table 1).
//!
//! The six Table-1 codes (`INPUT`, `WEIGHT`, `BIAS`, `ACT`, `MLP`,
//! `OUTPUT`) plus our documented training extensions (`TARGET`, `TRAIN`,
//! `FIXED`, `NET` block markers — DESIGN.md §4, S4/S20). Example:
//!
//! ```text
//! NET xor_net
//! FIXED 10 saturate
//! INPUT x 16 2            ; 16 x 2 data matrix (batch x features)
//! WEIGHT w0 2 8
//! BIAS b0 8
//! ACT a0 tanh shift=5 mode=clamp interp=1
//! MLP h x w0 b0 a0        ; Table 1: MLP OUTMAT INMAT INMAT INVEC INVEC
//! WEIGHT w1 8 2
//! BIAS b1 2
//! ACT a1 identity shift=5 mode=clamp interp=1
//! MLP out h w1 b1 a1
//! OUTPUT out
//! TARGET y 16 2
//! TRAIN lr=0.00390625     ; expands to backprop + SGD update waves
//! ```
//!
//! `parse` produces the AST; `lower::lower_file` type-checks the net and
//! produces one executable [`crate::assembler::Program`] per `NET` block.

pub mod ast;
pub mod lower;
pub mod parser;

pub use ast::{AsmFile, AsmNet, Directive, Item};
pub use lower::{lower_file, lower_net, AsmError, LoweredNet};
pub use parser::{parse, ParseError};
