//! AST of the neural-network assembly language (paper §3.1, Table 1).

use crate::nn::lut::{ActKind, AddrMode};

/// One parsed directive with its source line (1-based) for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct Item {
    /// 1-based source line.
    pub line: usize,
    /// The directive.
    pub dir: Directive,
}

/// Table-1 codes plus the training extensions (`TARGET`, `TRAIN`) and the
/// datapath selector (`FIXED`) — extensions documented in DESIGN.md.
#[derive(Debug, Clone, PartialEq)]
pub enum Directive {
    /// `NET <name>` — begins a network block.
    Net { name: String },
    /// `FIXED <frac_bits> <wrap|saturate>` — datapath format.
    Fixed { frac_bits: u32, saturate: bool },
    /// `INPUT <name> <N> <M>` — "Loads an N X M data matrix" (N = batch).
    Input { name: String, rows: usize, cols: usize },
    /// `WEIGHT <name> <N> <M>` — "Loads an N X M weight matrix".
    Weight { name: String, rows: usize, cols: usize },
    /// `BIAS <name> <N>` — "Loads a bias vector with size N".
    Bias { name: String, size: usize },
    /// `ACT <name> <kind> [shift=k] [mode=wrap|clamp] [interp=0|1]` —
    /// "Loads an activation lookup table" (table size is fixed at 1024,
    /// one RAMB18).
    Act {
        name: String,
        kind: ActKind,
        shift: Option<u32>,
        mode: Option<AddrMode>,
        interp: Option<bool>,
    },
    /// `MLP <out> <in> <weight> <bias> <act>` — "Executes a MLP layer".
    Mlp { out: String, input: String, weight: String, bias: String, act: String },
    /// `OUTPUT <name>` — "Stores data matrix".
    Output { name: String },
    /// `TARGET <name> <N> <M>` — training targets (extension).
    Target { name: String, rows: usize, cols: usize },
    /// `TRAIN lr=<f64>` — expand to a backprop + SGD step (extension).
    Train { lr: f64 },
}

/// A parsed file: one or more network blocks.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AsmFile {
    /// Network blocks in file order.
    pub nets: Vec<AsmNet>,
}

/// One network block.
#[derive(Debug, Clone, PartialEq)]
pub struct AsmNet {
    /// `NET` name.
    pub name: String,
    /// Items in block order.
    pub items: Vec<Item>,
}
