//! Analytic performance and cost models (paper §3.4, §4.1, §5).
//!
//! * [`group`] — Eqns 5–9: per-processor-group cycle counts, efficiency
//!   `E(N_I)`, processing rate `P(N_I)` and throughput `R(N_I)`, with the
//!   paper's published per-op constants, reproducing the §4.1 worked
//!   examples digit for digit. Also a *structural* cycle model derived
//!   from our simulator's measured pipeline (used by the fast simulator).
//! * [`catalog`] — Table 8's nine FPGA parts with DDR geometry, price, and
//!   device resources; Eqns 10–11 (DDR throughput `R` and
//!   throughput-per-cost `F`).

pub mod catalog;
pub mod group;

pub use catalog::{FpgaPart, CATALOG};
pub use group::{GroupPerf, OpClass, PerfModel};
