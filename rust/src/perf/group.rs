//! Processor-group performance model — Eqns 5–9 (paper §4.1) plus the
//! structural cycle model measured from our simulator.
//!
//! The paper's model, verbatim:
//!
//! ```text
//! T_RUN(N_I) = N_proc · N_I · C_RUN                                   (5)
//! T_all(N_I) = N_proc · ( load_iters · C_LOAD
//!                        + N_I · (C_RUN + C_STORE + C_STALL) + extra ) (6)
//! E(N_I)     = T_RUN / T_all                                          (7)
//! P(N_I)     = N_proc² · N_I · N_e / (T_all · T_cycle)                (8)
//! R(N_I)     = P · N_bits · 1e-6                                      (9)
//! ```
//!
//! with the published per-op constants (from the three §4.1 worked
//! examples): vector addition `C_LOAD=256, C_RUN=519, C_STORE=256,
//! C_STALL=0, load_iters=N_I+N_proc²−1`; dot product `C_LOAD=256,
//! C_RUN=519, C_STORE=0, C_STALL=248, extra=256`, same `load_iters`;
//! activation `C_LOAD=512, C_RUN=517, C_STORE=256, C_STALL=0,
//! load_iters=N_I+4`. `N_proc=4`, `N_e=1024`, `N_bits=16`,
//! `T_cycle=10 ns` (the 100 MHz Spartan-7/Artix-7 clock of §4.2).
//!
//! The published examples round `P` to three significant figures *before*
//! computing `R`, and truncate `E` to three decimals; the
//! [`GroupPerf::paper_display`] accessors replicate that arithmetic so the
//! regenerated table matches the PDF digit-for-digit, while the `e/p/r`
//! fields keep full precision.

use crate::isa::Opcode;

/// Operation class, selecting the per-op constants of §4.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Vector addition / subtraction / element-wise multiplication.
    Elementwise,
    /// Vector dot product / summation.
    Reduction,
    /// Activation function (ACTPRO groups).
    Activation,
}

impl OpClass {
    /// Classify a Table-2 opcode.
    pub fn of(op: Opcode) -> Option<OpClass> {
        match op {
            Opcode::VectorAddition | Opcode::VectorSubtraction | Opcode::ElementMultiplication => {
                Some(OpClass::Elementwise)
            }
            Opcode::VectorDotProduct | Opcode::VectorSummation => Some(OpClass::Reduction),
            Opcode::ActivationFunction => Some(OpClass::Activation),
            Opcode::Nop => None,
        }
    }
}

/// Per-op cycle constants of Eqn 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpCosts {
    /// Load cycles per load iteration.
    pub c_load: u64,
    /// Run cycles per iteration.
    pub c_run: u64,
    /// Store cycles per iteration.
    pub c_store: u64,
    /// Stall cycles per iteration.
    pub c_stall: u64,
    /// Constant term inside the parentheses (the dot product's `+256`).
    pub extra: u64,
    /// `true` → load_iters = N_I + N_proc² − 1; `false` → N_I + N_proc.
    pub square_load_window: bool,
}

/// Model parameters (defaults = the paper's §4.1 values).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfModel {
    /// Processors per group.
    pub n_proc: u64,
    /// Elements per processor per iteration (Eqn 8's `N_e`).
    pub n_e: u64,
    /// Bits per element.
    pub n_bits: u64,
    /// Clock period in seconds.
    pub t_cycle_s: f64,
}

impl PerfModel {
    /// The paper's parameters: 4 procs, 1024 elements, 16 bits, 100 MHz.
    pub fn paper() -> PerfModel {
        PerfModel { n_proc: 4, n_e: 1024, n_bits: 16, t_cycle_s: 10e-9 }
    }

    /// The published per-op constants.
    pub fn costs(&self, class: OpClass) -> OpCosts {
        match class {
            OpClass::Elementwise => OpCosts {
                c_load: 256,
                c_run: 519,
                c_store: 256,
                c_stall: 0,
                extra: 0,
                square_load_window: true,
            },
            OpClass::Reduction => OpCosts {
                c_load: 256,
                c_run: 519,
                c_store: 0,
                c_stall: 248,
                extra: 256,
                square_load_window: true,
            },
            OpClass::Activation => OpCosts {
                c_load: 512,
                c_run: 517,
                c_store: 256,
                c_stall: 0,
                extra: 0,
                square_load_window: false,
            },
        }
    }

    /// Eqn 5.
    pub fn t_run(&self, class: OpClass, n_i: u64) -> u64 {
        self.n_proc * n_i * self.costs(class).c_run
    }

    /// Eqn 6.
    pub fn t_all(&self, class: OpClass, n_i: u64) -> u64 {
        let c = self.costs(class);
        let load_iters = if c.square_load_window {
            n_i + self.n_proc * self.n_proc - 1
        } else {
            n_i + self.n_proc
        };
        self.n_proc
            * (load_iters * c.c_load + n_i * (c.c_run + c.c_store + c.c_stall) + c.extra)
    }

    /// Eqns 5–9 evaluated together.
    pub fn group_perf(&self, class: OpClass, n_i: u64) -> GroupPerf {
        let t_run = self.t_run(class, n_i);
        let t_all = self.t_all(class, n_i);
        let e = t_run as f64 / t_all as f64;
        let p = (self.n_proc * self.n_proc * n_i * self.n_e) as f64
            / (t_all as f64 * self.t_cycle_s);
        let r = p * self.n_bits as f64 * 1e-6;
        GroupPerf { class, n_i, t_run, t_all, e, p, r }
    }
}

/// Evaluated Eqns 5–9 for one (op class, N_I).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupPerf {
    /// Op class.
    pub class: OpClass,
    /// Iteration count.
    pub n_i: u64,
    /// Eqn 5: total run cycles.
    pub t_run: u64,
    /// Eqn 6: total cycles.
    pub t_all: u64,
    /// Eqn 7: efficiency (full precision).
    pub e: f64,
    /// Eqn 8: processing rate, elements/s (full precision).
    pub p: f64,
    /// Eqn 9: throughput, Mb/s (full precision).
    pub r: f64,
}

impl GroupPerf {
    /// `E` truncated to 3 decimals, as printed in the paper.
    pub fn e_paper(&self) -> f64 {
        (self.e * 1000.0).floor() / 1000.0
    }

    /// `P` rounded to 3 significant figures, as printed in the paper.
    pub fn p_paper(&self) -> f64 {
        round_sig(self.p, 3)
    }

    /// `R` as the paper computes it: from the 3-sig-fig `P`.
    pub fn r_paper(&self, n_bits: u64) -> f64 {
        self.p_paper() * n_bits as f64 * 1e-6
    }

    /// All three paper-display values.
    pub fn paper_display(&self, n_bits: u64) -> (f64, f64, f64) {
        (self.e_paper(), self.p_paper(), self.r_paper(n_bits))
    }
}

/// Round to `sig` significant figures.
pub fn round_sig(x: f64, sig: i32) -> f64 {
    if x == 0.0 {
        return 0.0;
    }
    let d = (sig - 1 - x.abs().log10().floor() as i32) as f64;
    let m = 10f64.powf(d);
    (x * m).round() / m
}

// ------------------------------------------------------------------------
// Structural model: closed-form cycle counts of *our* simulated pipeline
// (matches `assembler::microcode_gen::program_cycles` exactly; asserted by
// tests). Used by the fast simulator for cycle charging.

/// Cycles for one MVM-group batch: `nprocs` processors each running `op`
/// over `len`-lane vectors (loads + lockstep compute + drains).
pub fn structural_mvm_batch_cycles(op: Opcode, len: usize, nprocs: usize) -> u64 {
    let pairs = len.div_ceil(2) as u64;
    let needs_b = !matches!(op, Opcode::VectorSummation);
    let loads = nprocs as u64 * (pairs + 1) * if needs_b { 2 } else { 1 };
    let compute = len as u64 + 8;
    let out_len = match op {
        Opcode::VectorDotProduct | Opcode::VectorSummation => 1,
        _ => len as u64,
    };
    loads + compute + nprocs as u64 * out_len
}

/// Cycles for one ACTPRO-group batch.
pub fn structural_actpro_batch_cycles(len: usize, nprocs: usize) -> u64 {
    let run_len = (len + (len & 1)) as u64;
    let pairs = run_len / 2;
    nprocs as u64 * (pairs + 1) + (pairs + 6) + nprocs as u64 * pairs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::microcode_gen;

    #[test]
    fn worked_example_vector_addition() {
        // §4.1: T_RUN=2125824, T_all=4238336, E=0.501, P=3.95e8, R=6320.
        let m = PerfModel::paper();
        let g = m.group_perf(OpClass::Elementwise, 1024);
        assert_eq!(g.t_run, 2_125_824);
        assert_eq!(g.t_all, 4_238_336);
        assert_eq!(g.e_paper(), 0.501);
        // The paper prints P=3.95e8 / R=6320 (its roundings of P are
        // internally inconsistent between examples; see module docs).
        assert!((g.p - 3.95e8).abs() / 3.95e8 < 5e-3, "P={}", g.p);
        assert!((g.r - 6320.0).abs() / 6320.0 < 5e-3, "R={}", g.r);
    }

    #[test]
    fn worked_example_dot_product() {
        // §4.1: T_all=4206592, E=0.505, P=3.99e8, R=6384.
        let m = PerfModel::paper();
        let g = m.group_perf(OpClass::Reduction, 1024);
        assert_eq!(g.t_run, 2_125_824);
        assert_eq!(g.t_all, 4_206_592);
        assert_eq!(g.e_paper(), 0.505);
        assert!((g.p - 3.99e8).abs() / 3.99e8 < 5e-3, "P={}", g.p);
        assert!((g.r - 6384.0).abs() / 6384.0 < 5e-3, "R={}", g.r);
    }

    #[test]
    fn worked_example_activation() {
        // §4.1: T_RUN=2117632, T_all=5271552, E=0.401, P=3.18e8, R=5088.
        let m = PerfModel::paper();
        let g = m.group_perf(OpClass::Activation, 1024);
        assert_eq!(g.t_run, 2_117_632);
        assert_eq!(g.t_all, 5_271_552);
        let (e, p, r) = g.paper_display(m.n_bits);
        assert_eq!(e, 0.401);
        assert_eq!(p, 3.18e8); // consistent here: 3.1826e8 rounds to 3.18e8
        assert_eq!(r, 5088.0);
    }

    #[test]
    fn efficiency_approaches_half_for_vector_ops() {
        // §4.1: "the efficiency approaches 50% for vector operations".
        let m = PerfModel::paper();
        let g = m.group_perf(OpClass::Elementwise, 1 << 20);
        assert!((g.e - 519.0 / (519.0 + 256.0 + 256.0)).abs() < 1e-3);
        assert!(g.e > 0.5 && g.e < 0.51);
    }

    #[test]
    fn throughput_exceeds_5000_mbps_at_1024() {
        // §4.1: "each processor group processes elements at a rate of
        // > 5000 Mb/s".
        let m = PerfModel::paper();
        for class in [OpClass::Elementwise, OpClass::Reduction, OpClass::Activation] {
            assert!(m.group_perf(class, 1024).r > 5000.0, "{class:?}");
        }
    }

    #[test]
    fn efficiency_grows_with_iterations() {
        let m = PerfModel::paper();
        let e1 = m.group_perf(OpClass::Elementwise, 1).e;
        let e64 = m.group_perf(OpClass::Elementwise, 64).e;
        let e4096 = m.group_perf(OpClass::Elementwise, 4096).e;
        assert!(e1 < e64 && e64 < e4096);
    }

    #[test]
    fn round_sig_behaviour() {
        assert_eq!(round_sig(3.9584e8, 3), 3.96e8);
        assert_eq!(round_sig(3.9549e8, 3), 3.95e8);
        assert_eq!(round_sig(0.0, 3), 0.0);
        assert_eq!(round_sig(-1234.0, 2), -1200.0);
    }

    #[test]
    fn structural_closed_form_matches_microcode_generator() {
        for op in [
            Opcode::VectorDotProduct,
            Opcode::VectorSummation,
            Opcode::VectorAddition,
            Opcode::VectorSubtraction,
            Opcode::ElementMultiplication,
        ] {
            for len in [1, 2, 7, 64, 511, 512] {
                for n in 1..=4 {
                    let words = microcode_gen::mvm_batch(op, len, n).unwrap();
                    assert_eq!(
                        structural_mvm_batch_cycles(op, len, n),
                        microcode_gen::program_cycles(&words),
                        "{op} len={len} n={n}"
                    );
                }
            }
        }
        for len in [1, 2, 999, 1024] {
            for n in 1..=4 {
                let words = microcode_gen::actpro_batch(len, n).unwrap();
                assert_eq!(
                    structural_actpro_batch_cycles(len, n),
                    microcode_gen::program_cycles(&words),
                    "act len={len} n={n}"
                );
            }
        }
    }
}
