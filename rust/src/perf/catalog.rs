//! FPGA catalog + DDR throughput/cost model (paper §5, Table 8,
//! Eqns 10–11).
//!
//! "The main limiting factor in the FPGAs' performances is the DDR
//! throughput R... Spartan-7 XC7S75-2 was selected as the best FPGA
//! because the XC7S75-2 has the highest performance/cost ratio."
//!
//! Table 8 columns (IO pins, DDR channels, DDR bus clock, cost in CAD)
//! are from the paper; device resources (LUTs, FFs, RAMB18, DSPs) are
//! from Xilinx DS180 (the paper's ref [10]) and feed Eqns 3–4 in
//! `assembler::resource`. The FPGA fabric clock is §4.2's 100 MHz for both
//! Spartan-7 and Artix-7.

/// One catalog entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaPart {
    /// Part name as in Table 8 (family + speed grade).
    pub name: &'static str,
    /// IO pin count (Table 8).
    pub io_pins: u32,
    /// Number of 32-bit DDR RAM channels (Table 8, `N_DDR`).
    pub ddr_channels: u32,
    /// DDR bus clock in MHz (Table 8, `CLK_DDR`).
    pub ddr_clock_mhz: f64,
    /// Unit cost in CAD (Table 8).
    pub cost_cad: f64,
    /// Fabric clock in MHz (§4.2: 100 for Spartan-7/Artix-7).
    pub fpga_clock_mhz: f64,
    /// 6-input LUTs (DS180).
    pub luts: u32,
    /// Flip-flops (DS180).
    pub ffs: u32,
    /// RAMB18E1 blocks (DS180; 2 × RAMB36 count).
    pub bram18: u32,
    /// DSP48E1 slices (DS180).
    pub dsps: u32,
}

/// DDR bus width in bits (Eqn 10's `N_bits`; "32 bit DDR RAM channels").
pub const DDR_BUS_BITS: f64 = 32.0;

impl FpgaPart {
    /// Eqn 10: DDR throughput `R = CLK_DDR · 2 · N_bits · N_DDR` in Mb/s
    /// (DDR = double data rate, hence the factor 2).
    pub fn ddr_throughput_mbps(&self) -> f64 {
        self.ddr_clock_mhz * 2.0 * DDR_BUS_BITS * self.ddr_channels as f64
    }

    /// Eqn 11: throughput-to-cost ratio `F = R / C` in Mb/s/CAD.
    pub fn perf_cost(&self) -> f64 {
        self.ddr_throughput_mbps() / self.cost_cad
    }

    /// `F` truncated to 2 decimals, as printed in Table 8.
    pub fn perf_cost_paper(&self) -> f64 {
        (self.perf_cost() * 100.0).floor() / 100.0
    }

    /// DDR bandwidth in bytes per second.
    pub fn ddr_bytes_per_sec(&self) -> f64 {
        self.ddr_throughput_mbps() * 1e6 / 8.0
    }

    /// DDR bytes transferable per FPGA fabric cycle (drives the DMA cost
    /// model in `hw::machine`).
    pub fn ddr_bytes_per_cycle(&self) -> f64 {
        self.ddr_bytes_per_sec() / (self.fpga_clock_mhz * 1e6)
    }

    /// Fabric clock period in seconds.
    pub fn t_cycle_s(&self) -> f64 {
        1.0 / (self.fpga_clock_mhz * 1e6)
    }

    /// Look up a part by name.
    pub fn by_name(name: &str) -> Option<&'static FpgaPart> {
        CATALOG.iter().find(|p| p.name == name)
    }

    /// The paper's selected part (§5/§6).
    pub fn selected() -> &'static FpgaPart {
        FpgaPart::by_name("XC7S75-2").unwrap()
    }
}

/// Table 8's nine candidate parts.
pub const CATALOG: [FpgaPart; 9] = [
    FpgaPart {
        name: "XC7S50-1",
        io_pins: 250,
        ddr_channels: 2,
        ddr_clock_mhz: 333.33,
        cost_cad: 75.94,
        fpga_clock_mhz: 100.0,
        luts: 32_600,
        ffs: 65_200,
        bram18: 150,
        dsps: 120,
    },
    FpgaPart {
        name: "XC7S75-1",
        io_pins: 400,
        ddr_channels: 4,
        ddr_clock_mhz: 333.33,
        cost_cad: 134.46,
        fpga_clock_mhz: 100.0,
        luts: 48_000,
        ffs: 96_000,
        bram18: 180,
        dsps: 140,
    },
    FpgaPart {
        name: "XC7S100-1",
        io_pins: 400,
        ddr_channels: 4,
        ddr_clock_mhz: 333.33,
        cost_cad: 163.73,
        fpga_clock_mhz: 100.0,
        luts: 64_000,
        ffs: 128_000,
        bram18: 240,
        dsps: 160,
    },
    FpgaPart {
        name: "XC7S50-2",
        io_pins: 250,
        ddr_channels: 2,
        ddr_clock_mhz: 400.0,
        cost_cad: 95.11,
        fpga_clock_mhz: 100.0,
        luts: 32_600,
        ffs: 65_200,
        bram18: 150,
        dsps: 120,
    },
    FpgaPart {
        name: "XC7S75-2",
        io_pins: 400,
        ddr_channels: 4,
        ddr_clock_mhz: 400.0,
        cost_cad: 147.95,
        fpga_clock_mhz: 100.0,
        luts: 48_000,
        ffs: 96_000,
        bram18: 180,
        dsps: 140,
    },
    FpgaPart {
        name: "XC7S100-2",
        io_pins: 400,
        ddr_channels: 4,
        ddr_clock_mhz: 400.0,
        cost_cad: 198.12,
        fpga_clock_mhz: 100.0,
        luts: 64_000,
        ffs: 128_000,
        bram18: 240,
        dsps: 160,
    },
    FpgaPart {
        name: "XC7A75T-1",
        io_pins: 300,
        ddr_channels: 3,
        ddr_clock_mhz: 333.33,
        cost_cad: 213.27,
        fpga_clock_mhz: 100.0,
        luts: 47_200,
        ffs: 94_400,
        bram18: 210,
        dsps: 180,
    },
    FpgaPart {
        name: "XC7A100T-1",
        io_pins: 300,
        ddr_channels: 3,
        ddr_clock_mhz: 333.33,
        cost_cad: 234.6,
        fpga_clock_mhz: 100.0,
        luts: 63_400,
        ffs: 126_800,
        bram18: 270,
        dsps: 240,
    },
    FpgaPart {
        name: "XC7A200T-1",
        io_pins: 500,
        ddr_channels: 5,
        ddr_clock_mhz: 333.33,
        cost_cad: 381.95,
        fpga_clock_mhz: 100.0,
        luts: 134_600,
        ffs: 269_200,
        bram18: 730,
        dsps: 740,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table8_perf_cost_column_reproduced() {
        // The paper's DDR/Cost column, digit for digit (2-decimal
        // truncation of Eqn 11).
        let want = [
            ("XC7S50-1", 561.84),
            ("XC7S75-1", 634.63),
            ("XC7S100-1", 521.17),
            ("XC7S50-2", 538.32),
            ("XC7S75-2", 692.12),
            ("XC7S100-2", 516.85),
            ("XC7A75T-1", 300.08),
            ("XC7A100T-1", 272.80),
            ("XC7A200T-1", 279.26),
        ];
        for (name, f) in want {
            let p = FpgaPart::by_name(name).unwrap();
            assert_eq!(p.perf_cost_paper(), f, "{name}");
        }
    }

    #[test]
    fn xc7s75_2_is_argmax() {
        // §5: "Spartan-7 XC7S75-2 was selected as the best FPGA because
        // the XC7S75-2 has the highest performance/cost ratio."
        let best = CATALOG
            .iter()
            .max_by(|a, b| a.perf_cost().partial_cmp(&b.perf_cost()).unwrap())
            .unwrap();
        assert_eq!(best.name, "XC7S75-2");
        assert_eq!(FpgaPart::selected().name, "XC7S75-2");
    }

    #[test]
    fn eqn10_throughput_values() {
        assert_eq!(FpgaPart::by_name("XC7S75-2").unwrap().ddr_throughput_mbps(), 102_400.0);
        let r = FpgaPart::by_name("XC7S50-1").unwrap().ddr_throughput_mbps();
        assert!((r - 42_666.24).abs() < 1e-6);
    }

    #[test]
    fn ddr_bytes_per_cycle_sane() {
        // XC7S75-2: 102400 Mb/s = 12.8 GB/s over a 100 MHz fabric
        // → 128 bytes per fabric cycle.
        let p = FpgaPart::selected();
        assert!((p.ddr_bytes_per_cycle() - 128.0).abs() < 1e-9);
    }

    #[test]
    fn group_throughput_is_one_fifth_of_ddr2_channel() {
        // §4.1: ">5000 Mb/s, which is 1/5 the bandwidth of a 32 bit DDR2
        // RAM" — one 333 MHz channel is ~21333 Mb/s; 5088/21333 ≈ 0.24,
        // 6320/21333 ≈ 0.30: the claim holds to within the paper's
        // rounding for the activation figure ≈ 1/4..1/5.
        let ch: f64 = 333.33 * 2.0 * 32.0;
        assert!((ch - 21333.12).abs() < 1e-6);
        assert!(5088.0 / ch < 0.25);
    }

    #[test]
    fn catalog_is_spartan_and_artix_only() {
        // §5: "Only the Spartan-7 and Artix-7 families were considered".
        for p in &CATALOG {
            assert!(p.name.starts_with("XC7S") || p.name.starts_with("XC7A"));
            assert_eq!(p.fpga_clock_mhz, 100.0);
        }
    }

    #[test]
    fn unknown_part_is_none() {
        assert!(FpgaPart::by_name("XC7K325T").is_none());
    }
}
