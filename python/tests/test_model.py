"""L2 model tests: forward/train-step shapes, semantics, and the
Pallas-vs-oracle agreement at the whole-model level; plus AOT lowering
smoke (HLO text is produced and loads back through XlaComputation).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref

CFGKW = dict(frac_bits=10, saturate=True, shift=5, clamp=True, interp=True)


def make_net(rng, dims, batch):
    params = [
        (
            rng.integers(-500, 500, size=(dims[i], dims[i + 1]), dtype=np.int64).astype(np.int16),
            rng.integers(-200, 200, size=(dims[i + 1],), dtype=np.int64).astype(np.int16),
        )
        for i in range(len(dims) - 1)
    ]
    acts = [ref.lut_build("relu", False, 10, True, 5) for _ in range(len(dims) - 2)]
    acts.append(ref.lut_build("identity", False, 10, True, 5))
    dacts = [ref.lut_build("relu", True, 10, True, 5) for _ in range(len(dims) - 2)]
    dacts.append(ref.lut_build("identity", True, 10, True, 5))
    x = rng.integers(-1024, 1024, size=(batch, dims[0]), dtype=np.int64).astype(np.int16)
    y = rng.integers(-1024, 1024, size=(batch, dims[-1]), dtype=np.int64).astype(np.int16)
    return x, y, params, acts, dacts


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 8))
def test_forward_pallas_equals_oracle(seed, batch):
    rng = np.random.default_rng(seed)
    x, _, params, acts, _ = make_net(rng, [6, 9, 4], batch)
    a = np.asarray(model.mlp_forward(x, params, acts, use_pallas=True, **CFGKW))
    b = np.asarray(model.mlp_forward(x, params, acts, use_pallas=False, **CFGKW))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_train_step_pallas_equals_oracle(seed):
    rng = np.random.default_rng(seed)
    x, y, params, acts, dacts = make_net(rng, [5, 7, 3], 6)
    lr = np.full(7, 4, np.int16)  # 4/1024
    oa, la, pa = model.mlp_train_step(
        x, y, params, acts, dacts, lr, use_pallas=True, **CFGKW)
    ob, lb, pb = model.mlp_train_step(
        x, y, params, acts, dacts, lr, use_pallas=False, **CFGKW)
    np.testing.assert_array_equal(np.asarray(oa), np.asarray(ob))
    assert int(np.asarray(la)) == int(np.asarray(lb))
    for (wa, ba), (wb, bb) in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
        np.testing.assert_array_equal(np.asarray(ba), np.asarray(bb))


def test_train_step_reduces_float_loss():
    # End-to-end sanity: repeated quantised SGD steps reduce the decoded
    # MSE on a small linear task.
    rng = np.random.default_rng(7)
    dims = [4, 1]
    params = [(ref.encode(rng.normal(0, 0.2, (4, 1)), 10), np.zeros(1, np.int16))]
    acts = [ref.lut_build("identity", False, 10, True, 5)]
    dacts = [ref.lut_build("identity", True, 10, True, 5)]
    lr = np.full(1, 8, np.int16)
    true_w = np.array([0.5, -0.25, 0.125, 0.3])
    losses = []
    for _ in range(40):
        xs = rng.uniform(-1, 1, (16, 4))
        ys = (xs @ true_w)[:, None]
        xq = ref.encode(xs, 10)
        yq = ref.encode(ys, 10)
        out, _, params = model.mlp_train_step(
            xq, yq, params, acts, dacts, lr, use_pallas=False, **CFGKW)
        err = ref.decode(np.asarray(out), 10) - ys
        losses.append(float((err ** 2).mean()))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.5, losses


def test_flat_wrappers_roundtrip():
    rng = np.random.default_rng(3)
    x, y, params, acts, dacts = make_net(rng, [5, 7, 3], 4)
    lr = np.full(7, 4, np.int16)
    flat = []
    for w, b in params:
        flat += [w, b]
    flat += acts + dacts + [lr]
    outs = model.flat_train_step(x, y, *flat, n_layers=2, use_pallas=False, **CFGKW)
    assert len(outs) == 2 + 2 * 2  # out, loss, (w,b)x2
    o2, l2, p2 = model.mlp_train_step(
        x, y, params, acts, dacts, lr, use_pallas=False, **CFGKW)
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(outs[2]), np.asarray(p2[0][0]))


@pytest.mark.parametrize("lower", [aot.lower_vec_ops, aot.lower_mlp_fwd, aot.lower_mlp_train])
def test_aot_lowers_to_hlo_text(lower):
    text = aot.to_hlo_text(lower())
    assert "HloModule" in text
    assert len(text) > 200


def test_manifest_is_valid_toml_subset():
    m = aot.manifest()
    assert "[model]" in m and "dims = [15, 16, 10]" in m
    assert "frac_bits = 10" in m
    assert 'mlp_train = "mlp_train.hlo.txt"' in m
