"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtyped values, and LUT/datapath parameters and
asserts bit-exact agreement between `kernels.mvm_layer.mlp_layer`
(Pallas, interpret=True) and the oracle path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mvm_layer, ref

I16 = st.integers(min_value=-32768, max_value=32767)


def arr16(rng, *shape, amp=32768):
    return rng.integers(-amp, amp, size=shape, dtype=np.int64).astype(np.int16)


@settings(max_examples=40, deadline=None)
@given(
    batch=st.integers(1, 9),
    n_in=st.integers(1, 24),
    n_out=st.integers(1, 17),
    frac_bits=st.sampled_from([7, 10]),
    saturate=st.booleans(),
    clamp=st.booleans(),
    interp=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_layer_matches_ref(batch, n_in, n_out, frac_bits, saturate,
                                  clamp, interp, seed):
    rng = np.random.default_rng(seed)
    shift = frac_bits - 5 if clamp else frac_bits
    x = arr16(rng, batch, n_in, amp=4000)
    w = arr16(rng, n_in, n_out, amp=2000)
    b = arr16(rng, n_out, amp=2000)
    table = ref.lut_build("relu", False, frac_bits, clamp, shift)
    kw = dict(frac_bits=frac_bits, saturate=saturate, shift=shift,
              clamp=clamp, interp=interp)
    got = np.asarray(mvm_layer.mlp_layer(x, w, b, table, **kw))
    want = np.asarray(mvm_layer.mlp_layer_ref(x, w, b, table, **kw))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 64),
    frac_bits=st.sampled_from([7, 10]),
    saturate=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_vector_ops_reference_semantics(n, frac_bits, saturate, seed):
    """The jnp primitives implement the documented fixed-point semantics
    (checked against independent numpy integer arithmetic)."""
    rng = np.random.default_rng(seed)
    a = arr16(rng, n)
    b = arr16(rng, n)
    a64 = a.astype(np.int64)
    b64 = b.astype(np.int64)

    def nar(v):
        if saturate:
            return np.clip(v, -32768, 32767).astype(np.int16)
        return (np.asarray(v, np.int64) & 0xFFFF).astype(np.uint16).astype(np.int16)

    np.testing.assert_array_equal(
        np.asarray(ref.vadd(a, b, saturate)), nar(a64 + b64))
    np.testing.assert_array_equal(
        np.asarray(ref.vsub(a, b, saturate)), nar(a64 - b64))
    np.testing.assert_array_equal(
        np.asarray(ref.vmul(a, b, frac_bits, saturate)),
        nar((a64 * b64) >> frac_bits))
    assert np.asarray(ref.vdot(a, b, frac_bits, saturate)) == nar(
        (a64 * b64).sum() >> frac_bits)
    assert np.asarray(ref.vsum(a, saturate)) == nar(a64.sum())


@settings(max_examples=40, deadline=None)
@given(
    x=I16,
    shift=st.integers(0, 12),
    clamp=st.booleans(),
    kind=st.sampled_from(["relu", "sigmoid", "tanh", "identity"]),
)
def test_lut_addressing(x, shift, clamp, kind):
    table = ref.lut_build(kind, False, 7, clamp, shift)
    assert table.shape == (1024,)
    a = int(np.asarray(ref.lut_addr(np.int16(x), shift, clamp)))
    assert 0 <= a < 1024
    if clamp:
        expect = min(max((x >> shift) + 512, 0), 1023)
    else:
        expect = (x >> shift) & 1023
    assert a == expect


def test_lut_interp_relu_exact_in_linear_region():
    # With interpolation, ReLU is exact away from the kink (same property
    # asserted in rust/src/nn/lut.rs tests).
    f = 7
    table = ref.lut_build("relu", False, f, True, f)
    xs = np.arange(200, 16000, 37, dtype=np.int16)
    ys = np.asarray(ref.lut_apply(xs, table, f, True, True, False))
    np.testing.assert_array_equal(ys[xs >= 128], xs[xs >= 128])


def test_encode_decode_roundtrip():
    xs = np.linspace(-20, 20, 333)
    q = ref.encode(xs, 10)
    back = ref.decode(q, 10)
    assert np.max(np.abs(back - xs)) <= 0.5 / 1024 + 1e-12


@pytest.mark.parametrize("frac_bits", [7, 10])
def test_dot_accumulates_before_rescale(frac_bits):
    # 2^frac_bits ones dotted with ones: products are 1 each, the sum
    # reaches 2^frac_bits and only then is rescaled — per-element rescale
    # would give 0.
    n = 1 << frac_bits
    a = np.ones(n, np.int16)
    assert int(np.asarray(ref.vdot(a, a, frac_bits, False))) == 1
