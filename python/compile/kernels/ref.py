"""Pure-jnp oracle of the Matrix Machine's fixed-point datapath.

Single source of truth on the Python side, mirroring `rust/src/fixed`
and `rust/src/nn/lut.rs` **bit-exactly** (asserted by the integration
test `rust/tests/golden.rs` through the AOT artifacts, and by
`python/tests` against the Pallas kernel):

* values are Q(16, F) signed fixed point (default F = 7, paper sec. 2);
* dot products accumulate in 64-bit (the DSP48E1's 48-bit accumulator
  never overflows at paper sizes), then shift right by F and narrow;
* narrowing is two's-complement truncation (``wrap``) or saturation
  (``saturate``) — DESIGN.md sec. 3;
* activations are 1024-entry lookup tables addressed by ``x >> shift``
  with wrap (paper) or clamp addressing, optionally with linear
  interpolation on the residual bits.

Everything is plain jnp so it runs under jit, inside Pallas interpret
kernels, and lowers to HLO.
"""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

LUT_SIZE = 1024

I16_MIN = -32768
I16_MAX = 32767


def narrow(acc, saturate: bool):
    """Narrow a wide (int64) value to int16 per the round mode."""
    acc = jnp.asarray(acc, jnp.int64)
    if saturate:
        return jnp.clip(acc, I16_MIN, I16_MAX).astype(jnp.int16)
    return acc.astype(jnp.int16)  # two's-complement wrap


def vadd(a, b, saturate: bool):
    """VECTOR_ADDITION (lane-wise)."""
    return narrow(a.astype(jnp.int64) + b.astype(jnp.int64), saturate)


def vsub(a, b, saturate: bool):
    """VECTOR_SUBTRACTION (lane-wise)."""
    return narrow(a.astype(jnp.int64) - b.astype(jnp.int64), saturate)


def vmul(a, b, frac_bits: int, saturate: bool):
    """ELEMENT_MULTIPLICATION: (a*b) >> F, narrowed."""
    prod = a.astype(jnp.int64) * b.astype(jnp.int64)
    return narrow(prod >> frac_bits, saturate)


def vdot(a, b, frac_bits: int, saturate: bool):
    """VECTOR_DOT_PRODUCT along the last axis: Σ a·b >> F, narrowed."""
    acc = jnp.sum(a.astype(jnp.int64) * b.astype(jnp.int64), axis=-1)
    return narrow(acc >> frac_bits, saturate)


def vsum(a, saturate: bool):
    """VECTOR_SUMMATION along the last axis (no shift)."""
    return narrow(jnp.sum(a.astype(jnp.int64), axis=-1), saturate)


def matmul_q(x, w, frac_bits: int, saturate: bool):
    """Batched z = narrow((x @ w) >> F) — a wave of VECTOR_DOT_PRODUCTs."""
    acc = x.astype(jnp.int64) @ w.astype(jnp.int64)
    return narrow(acc >> frac_bits, saturate)


def lut_addr(x, shift: int, clamp: bool):
    """Table address of Q.F input ``x`` (ACTPRO shift stage, fig. 9)."""
    shifted = x.astype(jnp.int32) >> shift
    if clamp:
        return jnp.clip(shifted + LUT_SIZE // 2, 0, LUT_SIZE - 1)
    return (shifted & (LUT_SIZE - 1)).astype(jnp.int32)


def lut_apply(x, table, shift: int, clamp: bool, interp: bool, saturate: bool):
    """ACTIVATION_FUNCTION: shift → lookup [→ interpolate], narrowed."""
    a = lut_addr(x, shift, clamp)
    y0 = table[a].astype(jnp.int64)
    if not interp or shift == 0:
        return y0.astype(jnp.int16)
    frac = x.astype(jnp.int64) & ((1 << shift) - 1)
    if clamp:
        a1 = jnp.minimum(a + 1, LUT_SIZE - 1)
    else:
        a1 = (a + 1) & (LUT_SIZE - 1)
    y1 = table[a1].astype(jnp.int64)
    return narrow(y0 + (((y1 - y0) * frac) >> shift), saturate)


# -------------------------------------------------------------- LUT build
# (numpy, build-time only — mirrors rust ActLut::build)


def _act_f(kind: str, x):
    if kind == "relu":
        return np.maximum(0.0, x)
    if kind == "sigmoid":
        # numerically stable both tails (rust uses 1/(1+exp(-x)) in f64;
        # the two agree to f64 precision over the LUT's input range)
        out = np.empty_like(x)
        pos = x >= 0
        out[pos] = 1.0 / (1.0 + np.exp(-x[pos]))
        ex = np.exp(x[~pos])
        out[~pos] = ex / (1.0 + ex)
        return out
    if kind == "tanh":
        return np.tanh(x)
    if kind == "identity":
        return x
    raise ValueError(f"unknown activation {kind!r}")


def _act_df(kind: str, x):
    if kind == "relu":
        return (x > 0.0).astype(np.float64)
    if kind == "sigmoid":
        s = _act_f("sigmoid", x)
        return s * (1.0 - s)
    if kind == "tanh":
        return 1.0 - np.tanh(x) ** 2
    if kind == "identity":
        return np.ones_like(x)
    raise ValueError(f"unknown activation {kind!r}")


def _from_f64(y, frac_bits: int, saturate: bool):
    """rust FixedSpec::from_f64: round half away from zero, then narrow."""
    scale = float(1 << frac_bits)
    q = np.sign(y) * np.floor(np.abs(y) * scale + 0.5)
    if saturate:
        q = np.clip(q, I16_MIN, I16_MAX)
    return q.astype(np.int64).astype(np.int16)


def lut_build(kind: str, deriv: bool, frac_bits: int, clamp: bool, shift: int,
              saturate: bool = False):
    """Build a 1024-entry activation table (mirrors rust ActLut::build)."""
    idx = np.arange(LUT_SIZE, dtype=np.int64)
    if clamp:
        v10 = idx - LUT_SIZE // 2
    else:
        v10 = (idx << (64 - 10)) >> (64 - 10)  # sign-extend 10 bits
    x_real = (v10 << shift).astype(np.float64) / float(1 << frac_bits)
    y = _act_df(kind, x_real) if deriv else _act_f(kind, x_real)
    y = np.clip(y, -255.0, 255.0)
    return _from_f64(y, frac_bits, saturate)


def encode(x, frac_bits: int, saturate: bool = False):
    """Encode real numbers into Q.F lanes (rust FixedSpec::from_f64)."""
    return _from_f64(np.asarray(x, np.float64), frac_bits, saturate)


def decode(q, frac_bits: int):
    """Decode Q.F lanes to floats."""
    return np.asarray(q, np.int64).astype(np.float64) / float(1 << frac_bits)
