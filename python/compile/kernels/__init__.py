"""L1 kernels: the Pallas MLP-layer kernel + the pure-jnp oracle."""
