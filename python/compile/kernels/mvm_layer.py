"""L1 Pallas kernel: one quantised MLP layer (the compute hot-spot).

The Matrix Machine computes a layer as a wave of `VECTOR_DOT_PRODUCT`s
(one per (sample, neuron)), a bias `VECTOR_ADDITION` wave, and an
`ACTIVATION_FUNCTION` wave on the ACTPRO groups (paper sec. 1.1, 4.1).
This kernel is the TPU re-expression of that pipeline (DESIGN.md
sec. Hardware-Adaptation):

* the MVM group's BRAM column-caching becomes `BlockSpec` staging of the
  `x`/`w` tiles into VMEM (here: whole small tiles, grid of 1 — layer
  dims are ≤512, i.e. ≤0.5 MB of VMEM, far under budget);
* the 4-lane DSP array becomes the MXU-fed matmul over the whole tile;
* the ACTPRO's shift + BRAM lookup becomes a gathered table lookup;
* the numerics are the hardware's, unchanged: i16 operands, wide
  accumulate, `>> F` rescale, wrap/saturate narrowing (`ref.narrow`).

``interpret=True`` always: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU behaviour is compile-only (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

jax.config.update("jax_enable_x64", True)


def _layer_kernel(x_ref, w_ref, b_ref, lut_ref, o_ref, *, frac_bits, saturate,
                  shift, clamp, interp):
    """z = narrow((x @ w) >> F); z = narrow(z + b); o = LUT(z)."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    table = lut_ref[...]
    z = ref.matmul_q(x, w, frac_bits, saturate)
    z = ref.vadd(z, b[None, :], saturate)
    o_ref[...] = ref.lut_apply(z, table, shift, clamp, interp, saturate)


def mlp_layer(x, w, b, table, *, frac_bits=7, saturate=False, shift=7,
              clamp=False, interp=False):
    """Run one quantised MLP layer as a Pallas kernel.

    Args:
      x: int16[B, n_in] activations.
      w: int16[n_in, n_out] weights.
      b: int16[n_out] biases.
      table: int16[1024] activation lookup table.
    Returns:
      int16[B, n_out] activations.
    """
    batch, _ = x.shape
    n_out = w.shape[1]
    kernel = functools.partial(
        _layer_kernel,
        frac_bits=frac_bits,
        saturate=saturate,
        shift=shift,
        clamp=clamp,
        interp=interp,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((batch, n_out), jnp.int16),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, w, b, table)


def mlp_layer_ref(x, w, b, table, *, frac_bits=7, saturate=False, shift=7,
                  clamp=False, interp=False):
    """The same layer straight from the jnp oracle (no Pallas)."""
    z = ref.matmul_q(x, w, frac_bits, saturate)
    z = ref.vadd(z, b[None, :], saturate)
    return ref.lut_apply(z, table, shift, clamp, interp, saturate)
