"""L2: the quantised MLP forward pass and SGD training step in JAX.

Mirrors the wave schedule that `rust/src/nn/lowering.rs` emits, op for
op and narrow for narrow, so the Rust simulator and the AOT-compiled
artifact are **bit-exact** (asserted by `rust/tests/golden.rs`):

forward (per layer): DOT wave → narrow(>>F) → ADD-bias wave → ACT wave;
loss: SUB → square (ELEM_MULT) → row SUMs → final SUM;
backward (per layer, last→first):
  deriv-LUT wave, ELEM_MULT (δ), DOT over batch columns (∂W),
  SUM over batch columns (∂b), DOT over weight rows (δ propagation),
  then ELEM_MULT by the learning-rate vector + SUB (in-place update).

The hot-spot layer computation routes through the L1 Pallas kernel
(`kernels.mvm_layer`), so the kernel lowers into the same HLO module.
"""

import jax
import jax.numpy as jnp

from .kernels import mvm_layer, ref

jax.config.update("jax_enable_x64", True)


def mlp_forward(x, params, act_tables, *, frac_bits, saturate, shift, clamp,
                interp, use_pallas=True):
    """Forward pass. `params` = [(w0, b0), (w1, b1), ...]."""
    layer = mvm_layer.mlp_layer if use_pallas else mvm_layer.mlp_layer_ref
    o = x
    for (w, b), table in zip(params, act_tables):
        o = layer(
            o, w, b, table,
            frac_bits=frac_bits, saturate=saturate, shift=shift, clamp=clamp,
            interp=interp,
        )
    return o


def mlp_train_step(x, y, params, act_tables, dact_tables, lr_vec, *,
                   frac_bits, saturate, shift, clamp, interp,
                   use_pallas=True):
    """One SGD step; returns (out, loss, new_params).

    `lr_vec` is the int16 learning-rate constant vector (length =
    max layer width), exactly like the machine's `lr` Const buffer.
    """
    f, s = frac_bits, saturate

    # ---- forward, keeping pre-activations (z) for backprop ----
    zs, os = [], []
    o = x
    for (w, b), table in zip(params, act_tables):
        z = ref.matmul_q(o, w, f, s)
        z = ref.vadd(z, b[None, :], s)
        if use_pallas:
            # The L1 kernel computes the fused layer; recomputing o from z
            # via the table keeps z available for backprop while the
            # Pallas path still covers the hot dot/bias portion.
            o = mvm_layer.mlp_layer(
                o, w, b, table,
                frac_bits=f, saturate=s, shift=shift, clamp=clamp,
                interp=interp,
            )
        else:
            o = ref.lut_apply(z, table, shift, clamp, interp, s)
        zs.append(z)
        os.append(o)
    out = os[-1]

    # ---- loss: d = o − y; loss = Σ (d⊙d rows summed) ----
    d = ref.vsub(out, y, s)
    sq = ref.vmul(d, d, f, s)
    lsum = ref.vsum(sq, s)  # per-sample row sums
    loss = ref.vsum(lsum, s)  # scalar

    # ---- backward ----
    new_params = list(params)
    nl = len(params)
    for l in range(nl - 1, -1, -1):
        w, b = params[l]
        n_out = w.shape[1]
        inp = x if l == 0 else os[l - 1]
        # δ = d ⊙ A'(z)
        g = ref.lut_apply(zs[l], dact_tables[l], shift, clamp, interp, s)
        d = ref.vmul(d, g, f, s)
        # ∂W[i,j] = dot over the batch of input col i with δ col j
        acc = inp.astype(jnp.int64).T @ d.astype(jnp.int64)
        gw = ref.narrow(acc >> f, s)
        # ∂b[j] = Σ_b δ[b,j] (no shift)
        gb = ref.narrow(d.astype(jnp.int64).sum(axis=0), s)
        # δ_{prev}[b,i] = dot(w row i, δ row b)
        if l > 0:
            acc = d.astype(jnp.int64) @ w.astype(jnp.int64).T
            d = ref.narrow(acc >> f, s)
        # SGD update (lr as an ELEM_MULT by the constant vector)
        lr = lr_vec[:n_out]
        gw = ref.vmul(gw, lr[None, :], f, s)
        new_w = ref.vsub(w, gw, s)
        gb = ref.vmul(gb, lr, f, s)
        new_b = ref.vsub(b, gb, s)
        new_params[l] = (new_w, new_b)

    return out, loss, new_params


def flat_train_step(x, y, *flat, n_layers, frac_bits, saturate, shift, clamp,
                    interp, use_pallas=True):
    """`mlp_train_step` with flattened arguments, for AOT export.

    flat = w0, b0, ..., w{L-1}, b{L-1}, act0.., dact0.., lr_vec
    Returns a flat tuple: (out, loss, new_w0, new_b0, ...).
    """
    params = [(flat[2 * i], flat[2 * i + 1]) for i in range(n_layers)]
    acts = list(flat[2 * n_layers:3 * n_layers])
    dacts = list(flat[3 * n_layers:4 * n_layers])
    lr_vec = flat[4 * n_layers]
    out, loss, new_params = mlp_train_step(
        x, y, params, acts, dacts, lr_vec,
        frac_bits=frac_bits, saturate=saturate, shift=shift, clamp=clamp,
        interp=interp, use_pallas=use_pallas,
    )
    flat_out = [out, loss]
    for w, b in new_params:
        flat_out.extend([w, b])
    return tuple(flat_out)


def flat_forward(x, *flat, n_layers, frac_bits, saturate, shift, clamp,
                 interp, use_pallas=True):
    """`mlp_forward` with flattened arguments, for AOT export."""
    params = [(flat[2 * i], flat[2 * i + 1]) for i in range(n_layers)]
    acts = list(flat[2 * n_layers:3 * n_layers])
    return (
        mlp_forward(
            x, params, acts,
            frac_bits=frac_bits, saturate=saturate, shift=shift, clamp=clamp,
            interp=interp, use_pallas=use_pallas,
        ),
    )
