//! Cluster scaling sweep (E-SCALE): makespan and throughput as the
//! number of MLPs (M) and boards (F) vary across the paper's three
//! scheduling regimes (sequential / 1:1 / divided), driven through
//! [`Session::train_many`] over compile-once artifacts.
//!
//! ```sh
//! cargo run --release --example cluster_scaling
//! ```
//!
//! [`Session::train_many`]: mfnn::Session::train_many

use mfnn::cluster::ClusterConfig;
use mfnn::fixed::FixedSpec;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::report::{f, Table};
use mfnn::session::NetJob;
use mfnn::util::Rng;
use mfnn::{CompileOptions, Compiler, Session};
use std::sync::Arc;

const LR: f64 = 1.0 / 128.0;

fn mk_jobs(compiler: &Compiler, m: usize, steps: usize) -> Vec<NetJob> {
    let fixed = FixedSpec::q(10).saturating();
    (0..m)
        .map(|i| {
            let seed = 100 + i as u64;
            let spec = MlpSpec::from_dims(
                &format!("job{i}"), &[15, 24, 10], ActKind::Relu, ActKind::Identity,
                fixed, LutParams::training(fixed),
            )
            .unwrap();
            // the compiler cache makes artifact reuse across sweep cells free
            let artifact =
                compiler.compile_spec(&spec, &CompileOptions::training(16, LR)).unwrap();
            let (train, test) =
                dataset::mini_digits(300, seed).split(0.8, &mut Rng::new(seed));
            NetJob {
                artifact,
                cfg: TrainConfig { batch: 16, lr: LR, steps, seed, log_every: 50 },
                train: Arc::new(train),
                test: Arc::new(test),
                resume: None,
            }
        })
        .collect()
}

fn main() -> Result<(), mfnn::Error> {
    let compiler = Compiler::new();
    let steps = 120;
    let mut t = Table::new(vec![
        "M (MLPs)", "F (boards)", "mode", "makespan (sim ms)", "Σ steps/s (sim)", "min acc",
    ])
    .with_title("cluster scaling: M MLPs × F boards (paper §2 scheduling cases)")
    .numeric();
    for (m, fboards) in [(1usize, 1usize), (2, 1), (4, 1), (4, 2), (4, 4), (2, 4), (1, 4), (1, 2)] {
        let jobs = mk_jobs(&compiler, m, steps);
        let cfg = ClusterConfig { boards: fboards, sync_every: 30, ..Default::default() };
        let report = Session::train_many(&cfg, &jobs)?;
        let total_steps: usize = report.results.iter().map(|r| r.steps).sum();
        let min_acc = report
            .results
            .iter()
            .map(|r| r.accuracy)
            .fold(f64::INFINITY, f64::min);
        t.row(vec![
            m.to_string(),
            fboards.to_string(),
            format!("{:?}", report.placement.mode),
            f(report.makespan_s * 1e3, 2),
            f(total_steps as f64 / report.makespan_s, 0),
            f(min_acc, 3),
        ]);
    }
    print!("{}", t.render());
    println!("({} artifacts compiled once and reused across all sweep cells)", compiler.cached());
    println!("expected shape: makespan grows ~linearly in M at fixed F (sequential),");
    println!("shrinks with F at fixed M (parallel), with weight-sync bus overhead");
    println!("making the divided mode sub-linear.");
    Ok(())
}
