//! **End-to-end driver** (E-E2E in DESIGN.md): train and test multiple
//! MLPs on a multi-FPGA cluster — the paper's whole point — through the
//! unified session front door, and log the loss curves, accuracies, and
//! simulated times.
//!
//! Workload: three different nets / datasets on 2 simulated XC7S75-2
//! boards (M > F → sequential queues) via [`Session::train_many`], then
//! ONE net divided over 3 boards (M < F → data-parallel with weight
//! averaging) via a cluster-target [`Session`], plus a float64 host
//! baseline for quality comparison. Results are recorded in
//! EXPERIMENTS.md §E-E2E.
//!
//! ```sh
//! cargo run --release --example train_cluster
//! ```
//!
//! [`Session`]: mfnn::Session
//! [`Session::train_many`]: mfnn::Session::train_many

use mfnn::cluster::{ClusterConfig, PlacementMode};
use mfnn::fixed::FixedSpec;
use mfnn::nn::dataset::{self, Dataset};
use mfnn::nn::float_ref::FloatMlp;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::report::{f, Table};
use mfnn::session::NetJob;
use mfnn::util::Rng;
use mfnn::{Compiler, Session, Target};
use std::sync::Arc;

const LR: f64 = 1.0 / 128.0;

fn job(
    compiler: &Compiler,
    name: &str,
    dims: &[usize],
    ds: Dataset,
    steps: usize,
    seed: u64,
) -> NetJob {
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        name, dims, ActKind::Relu, ActKind::Identity, fixed, LutParams::training(fixed),
    )
    .expect("valid spec");
    let artifact = compiler
        .compile_spec(&spec, &mfnn::CompileOptions::training(16, LR))
        .expect("compile");
    let (train, test) = ds.split(0.8, &mut Rng::new(seed));
    NetJob {
        artifact,
        cfg: TrainConfig { batch: 16, lr: LR, steps, seed, log_every: 20 },
        train: Arc::new(train),
        test: Arc::new(test),
        resume: None,
    }
}

/// Float64 host baseline with the same architecture/steps.
fn float_baseline(j: &NetJob) -> f64 {
    let spec = j.artifact.spec().expect("net artifact");
    let mut m = FloatMlp::init(spec, &mut Rng::new(j.cfg.seed));
    let mut r = Rng::new(j.cfg.seed ^ 0x5EED);
    let ds = &j.train;
    for _ in 0..j.cfg.steps {
        let ids: Vec<usize> =
            (0..j.cfg.batch).map(|_| r.gen_range(ds.len() as u64) as usize).collect();
        let xs: Vec<Vec<f64>> = ids.iter().map(|&i| ds.x[i].clone()).collect();
        let ys: Vec<Vec<f64>> = ids.iter().map(|&i| ds.y[i].clone()).collect();
        m.train_step(&xs, &ys, LR);
    }
    m.accuracy(&j.test.x, &j.test.y)
}

fn main() -> Result<(), mfnn::Error> {
    let compiler = Compiler::new();

    // ---- phase 1: M=3 jobs > F=2 boards → sequential queues ----
    let jobs = vec![
        job(&compiler, "digits", &[15, 24, 10], dataset::mini_digits(400, 11), 400, 11),
        job(&compiler, "moons", &[2, 16, 2], dataset::two_moons(300, 22), 300, 22),
        job(&compiler, "blobs", &[8, 16, 4], dataset::blobs(320, 4, 8, 33), 250, 33),
    ];
    let cfg = ClusterConfig { boards: 2, ..Default::default() };
    println!("== phase 1: {} MLPs on {} boards ==", jobs.len(), cfg.boards);
    let report = Session::train_many(&cfg, &jobs)?;
    assert_eq!(report.placement.mode, PlacementMode::Sequential);

    let mut t = Table::new(vec![
        "job", "boards", "steps", "first loss", "final loss", "accuracy",
        "float64 acc", "sim compute", "sim bus",
    ])
    .with_title(format!(
        "multi-MLP training (mode {:?}, simulated makespan {:.2} ms)",
        report.placement.mode,
        report.makespan_s * 1e3
    ))
    .numeric();
    for (j, jr) in jobs.iter().zip(&report.results) {
        let base = float_baseline(j);
        t.row(vec![
            jr.name.clone(),
            format!("{:?}", jr.boards),
            jr.steps.to_string(),
            f(jr.curve.first().unwrap().loss, 4),
            f(jr.curve.last().unwrap().loss, 4),
            f(jr.accuracy, 3),
            f(base, 3),
            format!("{:.2} ms", jr.sim_compute_s * 1e3),
            format!("{:.2} ms", jr.sim_bus_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    println!("loss curves (host-side MSE):");
    for jr in &report.results {
        let pts: Vec<String> =
            jr.curve.iter().map(|p| format!("{}:{:.4}", p.step, p.loss)).collect();
        println!("  {:<8} {}", jr.name, pts.join("  "));
    }
    println!("metrics: {:?}\n", report.metrics);

    // ---- phase 2: M=1 job < F=3 boards → divided (data parallel),
    //      as a single cluster-target Session ----
    let ds = dataset::mini_digits(600, 44);
    let (train, test) = ds.split(0.8, &mut Rng::new(44));
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        "digits_dp", &[15, 24, 10], ActKind::Relu, ActKind::Identity,
        fixed, LutParams::training(fixed),
    )
    .expect("valid spec");
    let artifact = compiler.compile_spec(&spec, &mfnn::CompileOptions::training(16, LR))?;
    let ccfg = ClusterConfig { boards: 3, sync_every: 30, ..Default::default() };
    println!("== phase 2: 1 MLP divided over {} boards ==", ccfg.boards);
    let mut session = Session::open(artifact, Target::Cluster(ccfg))?;
    let cfg = TrainConfig { batch: 16, lr: LR, steps: 360, seed: 44, log_every: 20 };
    let summary = session.train(&train, &cfg)?;
    let eval = session.evaluate(&test)?;
    println!(
        "digits_dp: boards {:?}, accuracy {:.3}, sync rounds {}, sim train {:.2} ms",
        summary.boards, eval.accuracy, summary.sync_rounds, summary.sim_seconds * 1e3,
    );
    for w in [0, summary.curve.len() - 1] {
        let p = &summary.curve[w];
        println!("  step {:>4}: loss {:.4}", p.step, p.loss);
    }
    Ok(())
}
