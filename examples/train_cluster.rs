//! **End-to-end driver** (E-E2E in DESIGN.md): train and test multiple
//! MLPs on a multi-FPGA cluster — the paper's whole point — and log the
//! loss curves, accuracies, and simulated times.
//!
//! Workload: three different nets / datasets on 2 simulated XC7S75-2
//! boards (M > F → sequential queues), then ONE net divided over 3
//! boards (M < F → data-parallel with weight averaging), plus a float64
//! host baseline for quality comparison. Results are recorded in
//! EXPERIMENTS.md §E-E2E.
//!
//! ```sh
//! cargo run --release --example train_cluster
//! ```

use mfnn::cluster::{run_cluster, ClusterConfig, Job, PlacementMode};
use mfnn::fixed::FixedSpec;
use mfnn::nn::dataset::{self, Dataset};
use mfnn::nn::float_ref::FloatMlp;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::report::{f, Table};
use mfnn::util::Rng;
use std::sync::Arc;

fn job(name: &str, dims: &[usize], ds: Dataset, steps: usize, seed: u64) -> Job {
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        name, dims, ActKind::Relu, ActKind::Identity, fixed, LutParams::training(fixed),
    )
    .expect("valid spec");
    let (train, test) = ds.split(0.8, &mut Rng::new(seed));
    Job {
        name: name.into(),
        spec,
        cfg: TrainConfig { batch: 16, lr: 1.0 / 128.0, steps, seed, log_every: 20 },
        train_data: Arc::new(train),
        test_data: Arc::new(test),
    }
}

/// Float64 host baseline with the same architecture/steps.
fn float_baseline(j: &Job) -> f64 {
    let mut m = FloatMlp::init(&j.spec, &mut Rng::new(j.cfg.seed));
    let mut r = Rng::new(j.cfg.seed ^ 0x5EED);
    let ds = &j.train_data;
    for _ in 0..j.cfg.steps {
        let ids: Vec<usize> =
            (0..j.cfg.batch).map(|_| r.gen_range(ds.len() as u64) as usize).collect();
        let xs: Vec<Vec<f64>> = ids.iter().map(|&i| ds.x[i].clone()).collect();
        let ys: Vec<Vec<f64>> = ids.iter().map(|&i| ds.y[i].clone()).collect();
        m.train_step(&xs, &ys, 1.0 / 128.0);
    }
    m.accuracy(&j.test_data.x, &j.test_data.y)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- phase 1: M=3 jobs > F=2 boards → sequential queues ----
    let jobs = vec![
        job("digits", &[15, 24, 10], dataset::mini_digits(400, 11), 400, 11),
        job("moons", &[2, 16, 2], dataset::two_moons(300, 22), 300, 22),
        job("blobs", &[8, 16, 4], dataset::blobs(320, 4, 8, 33), 250, 33),
    ];
    let cfg = ClusterConfig { boards: 2, ..Default::default() };
    println!("== phase 1: {} MLPs on {} boards ==", jobs.len(), cfg.boards);
    let report = run_cluster(&cfg, &jobs)?;
    assert_eq!(report.placement.mode, PlacementMode::Sequential);

    let mut t = Table::new(vec![
        "job", "boards", "steps", "first loss", "final loss", "accuracy",
        "float64 acc", "sim compute", "sim bus",
    ])
    .with_title(format!(
        "multi-MLP training (mode {:?}, simulated makespan {:.2} ms)",
        report.placement.mode,
        report.makespan_s * 1e3
    ))
    .numeric();
    for (j, jr) in jobs.iter().zip(&report.results) {
        let base = float_baseline(j);
        t.row(vec![
            jr.name.clone(),
            format!("{:?}", jr.boards),
            jr.steps.to_string(),
            f(jr.curve.first().unwrap().loss, 4),
            f(jr.curve.last().unwrap().loss, 4),
            f(jr.accuracy, 3),
            f(base, 3),
            format!("{:.2} ms", jr.sim_compute_s * 1e3),
            format!("{:.2} ms", jr.sim_bus_s * 1e3),
        ]);
    }
    print!("{}", t.render());
    println!("loss curves (host-side MSE):");
    for jr in &report.results {
        let pts: Vec<String> =
            jr.curve.iter().map(|p| format!("{}:{:.4}", p.step, p.loss)).collect();
        println!("  {:<8} {}", jr.name, pts.join("  "));
    }
    println!("metrics: {:?}\n", report.metrics);

    // ---- phase 2: M=1 job < F=3 boards → divided (data parallel) ----
    let dp_jobs = vec![job("digits_dp", &[15, 24, 10], dataset::mini_digits(600, 44), 360, 44)];
    let cfg = ClusterConfig { boards: 3, sync_every: 30, ..Default::default() };
    println!("== phase 2: 1 MLP divided over {} boards ==", cfg.boards);
    let report = run_cluster(&cfg, &dp_jobs)?;
    assert_eq!(report.placement.mode, PlacementMode::Divided);
    let jr = &report.results[0];
    println!(
        "{}: boards {:?}, accuracy {:.3}, sync rounds {}, critical-path compute {:.2} ms, bus {:.2} ms",
        jr.name, jr.boards, jr.accuracy, report.metrics.sync_rounds,
        jr.sim_compute_s * 1e3, jr.sim_bus_s * 1e3
    );
    for w in [0, report.results[0].curve.len() - 1] {
        let p = &jr.curve[w];
        println!("  step {:>4}: loss {:.4}", p.step, p.loss);
    }
    Ok(())
}
