//! Regenerate the paper's timing diagrams (Figs 7, 8, 10 — E-F7/E-F8/
//! E-F10 in DESIGN.md) from the cycle-accurate structural simulator.
//!
//! ```sh
//! cargo run --release --example timing_traces
//! ```

fn main() {
    print!("{}", mfnn::hw::trace_figures::all_figures());
    println!("\nLandmarks (asserted by unit tests):");
    println!("  Fig 7 : setup at cycle 1, dual-port commits from cycle 2");
    println!("  Fig 8 : read@2 → DSP 6-stage pipeline → P@8 → write@9; C_RUN(512) = 519");
    println!("  Fig 10: read@2 → shift@3 → LUT@4-5 → ctr@6 → write@7; C_RUN(1024) = 517");
}
