//! Quickstart: write a net in the paper's assembly language, compile it
//! once with the session [`Compiler`], open a [`Session`] on a simulated
//! Spartan-7 XC7S75-2, run one structurally-verified inference batch
//! through typed tensor handles, and print what happened.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! [`Compiler`]: mfnn::Compiler
//! [`Session`]: mfnn::Session

use mfnn::hw::FpgaDevice;
use mfnn::util::Rng;
use mfnn::{Compiler, Session, Target};

const NET: &str = "
NET quickstart
FIXED 10 saturate
INPUT x 8 4                            ; 8 x 4 data matrix (Table 1 INPUT)
WEIGHT w0 4 16
BIAS b0 16
ACT a0 relu shift=5 mode=clamp interp=1
MLP h x w0 b0 a0                       ; Table 1 MLP: OUT IN W B ACT
WEIGHT w1 16 3
BIAS b1 3
ACT a1 identity shift=5 mode=clamp interp=1
MLP scores h w1 b1 a1
OUTPUT scores
";

fn main() -> Result<(), mfnn::Error> {
    // 1) Compile once: text → validated, cached Artifact (program +
    //    symbol table + per-device execution plans).
    let compiler = Compiler::new();
    let artifact = compiler.compile_asm_net(NET)?;
    let program = artifact.program();
    println!(
        "compiled {:?}: {} waves, {} lane-ops, {} tensors",
        artifact.name(),
        program.waves().count(),
        program.total_lane_ops(),
        artifact.tensors().len()
    );

    // 2) Open a session on the paper's selected board (XC7S75-2:
    //    16 MVM groups + 4 ACTPRO groups by Eqns 3-4).
    let device = FpgaDevice::selected();
    let mut session = Session::open(artifact.clone(), Target::Board(device))?;

    // 3) Bind quantised data through typed handles (shapes were resolved
    //    at compile time; a typo'd name would say "did you mean …").
    let f = artifact.fixed();
    let mut rng = Rng::new(7);
    let mut rand = |n: usize, amp: f64| -> Vec<i16> {
        (0..n).map(|_| f.from_f64((rng.gen_f64() - 0.5) * amp)).collect()
    };
    for (name, amp) in
        [("x", 2.0), ("w0", 1.0), ("b0", 0.3), ("w1", 1.0), ("b1", 0.3)]
    {
        let h = artifact.tensor(name)?;
        let data = rand(h.len(), amp);
        session.write(&h, &data)?;
    }
    let stats = session.step_verified()?; // structural verification on

    // 4) Read results.
    let scores = session.read(&artifact.tensor("scores")?)?;
    println!("scores[0..3] = {:?} (Q5.10 → {:?})", &scores[..3],
        scores[..3].iter().map(|&q| f.to_f64(q)).collect::<Vec<_>>());
    println!(
        "{} cycles ({} dma, {} compute, {} lut, {} ring) = {:.3} µs on {} @100MHz",
        stats.cycles, stats.dma_cycles, stats.compute_cycles, stats.lut_cycles,
        stats.ring_cycles, stats.seconds(&device) * 1e6, device.part.name
    );
    Ok(())
}
