//! Quickstart: write a net in the paper's assembly language, run the
//! Matrix Assembler, execute one inference batch on a simulated
//! Spartan-7 XC7S75-2, and print what happened.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mfnn::asm::lower_file;
use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::util::Rng;

const NET: &str = "
NET quickstart
FIXED 10 saturate
INPUT x 8 4                            ; 8 x 4 data matrix (Table 1 INPUT)
WEIGHT w0 4 16
BIAS b0 16
ACT a0 relu shift=5 mode=clamp interp=1
MLP h x w0 b0 a0                       ; Table 1 MLP: OUT IN W B ACT
WEIGHT w1 16 3
BIAS b1 3
ACT a1 identity shift=5 mode=clamp interp=1
MLP scores h w1 b1 a1
OUTPUT scores
";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1) Matrix Assembler: text → validated vector program.
    let nets = lower_file(NET)?;
    let net = &nets[0];
    let program = &net.mlp.program;
    println!(
        "assembled {:?}: {} waves, {} lane-ops, {} buffers",
        net.spec.name,
        program.waves().count(),
        program.total_lane_ops(),
        program.buffers.len()
    );

    // 2) A Matrix Machine for the paper's selected board (XC7S75-2:
    //    16 MVM groups + 4 ACTPRO groups by Eqns 3-4).
    let device = FpgaDevice::selected();
    let mut machine = MatrixMachine::new(device, program)?;

    // 3) Bind quantised data and run.
    let f = net.spec.fixed;
    let mut rng = Rng::new(7);
    let mut rand = |n: usize, amp: f64| -> Vec<i16> {
        (0..n).map(|_| f.from_f64((rng.gen_f64() - 0.5) * amp)).collect()
    };
    machine.bind(program, "x", &rand(8 * 4, 2.0))?;
    machine.bind(program, "w0", &rand(4 * 16, 1.0))?;
    machine.bind(program, "b0", &rand(16, 0.3))?;
    machine.bind(program, "w1", &rand(16 * 3, 1.0))?;
    machine.bind(program, "b1", &rand(3, 0.3))?;
    let stats = machine.run_verified(program)?; // structural verification on

    // 4) Read results.
    let scores = machine.read(program, "scores")?;
    println!("scores[0..3] = {:?} (Q5.10 → {:?})", &scores[..3],
        scores[..3].iter().map(|&q| f.to_f64(q)).collect::<Vec<_>>());
    println!(
        "{} cycles ({} dma, {} compute, {} lut, {} ring) = {:.3} µs on {} @100MHz",
        stats.cycles, stats.dma_cycles, stats.compute_cycles, stats.lut_cycles,
        stats.ring_cycles, stats.seconds(&device) * 1e6, device.part.name
    );
    Ok(())
}
