//! Regenerate every numeric table of the paper (E-T2, E-T3, E-T8,
//! E-E34, E-W1 in DESIGN.md) from the implemented models and print them
//! side by side with the published values.
//!
//! ```sh
//! cargo run --release --example paper_tables
//! ```

use mfnn::assembler::resource::{ResourceModel, ACTPRO_PG_USAGE, MVM_PG_USAGE};
use mfnn::hw::FpgaDevice;
use mfnn::perf::catalog::CATALOG;
use mfnn::perf::group::{OpClass, PerfModel};
use mfnn::report::{f, Table};

fn main() {
    // Table 2 is structural (checked by tests); print the ISA as a table.
    let mut t2 = Table::new(vec!["Instruction", "Op code", "Description"])
        .with_title("Table 2 — instruction set architecture");
    for op in mfnn::isa::Opcode::ALL {
        t2.row(vec![op.mnemonic().into(), format!("{:03b}", op.bits()), op.description().into()]);
    }
    print!("{}", t2.render());

    let mut t3 = Table::new(vec!["Component", "LUTs", "FFs", "RAMB18Ks", "DSPs"])
        .with_title("Table 3 — processor group resource usages")
        .numeric();
    for (n, u) in [("MVM_PG", MVM_PG_USAGE), ("ACTPRO_PG", ACTPRO_PG_USAGE)] {
        t3.row(vec![
            n.into(),
            u.luts.to_string(),
            u.ffs.to_string(),
            u.bram18.to_string(),
            u.dsps.to_string(),
        ]);
    }
    print!("{}", t3.render());

    // §4.1 worked examples: published values beside our evaluation.
    let published = [
        ("vector addition", OpClass::Elementwise, 0.501, 6320.0),
        ("vector dot product", OpClass::Reduction, 0.505, 6384.0),
        ("activation function", OpClass::Activation, 0.401, 5088.0),
    ];
    let m = PerfModel::paper();
    let mut tw = Table::new(vec![
        "op (N_I=1024)", "T_RUN", "T_all", "E ours", "E paper", "R ours (Mb/s)", "R paper",
    ])
        .with_title("Sec 4.1 worked examples — Eqns 5-9")
        .numeric();
    for (name, class, e_pub, r_pub) in published {
        let g = m.group_perf(class, 1024);
        tw.row(vec![
            name.into(),
            g.t_run.to_string(),
            g.t_all.to_string(),
            f(g.e_paper(), 3),
            f(e_pub, 3),
            f(g.r, 0),
            f(r_pub, 0),
        ]);
    }
    print!("{}", tw.render());

    // Table 8 + Eqns 3-4 allocation.
    let mut t8 = Table::new(vec![
        "FPGA", "IO", "DDR ch", "DDR clk", "Cost CAD", "R Mb/s", "F ours", "MVM_PG", "ACTPRO_PG",
    ])
        .with_title("Table 8 — performance/cost (Eqns 10-11) + Eqns 3-4 allocation")
        .numeric();
    for p in &CATALOG {
        let d = FpgaDevice::new(p);
        let rm = ResourceModel::new(p);
        let _ = rm;
        t8.row(vec![
            p.name.into(),
            p.io_pins.to_string(),
            p.ddr_channels.to_string(),
            format!("{}", p.ddr_clock_mhz),
            format!("{}", p.cost_cad),
            f(p.ddr_throughput_mbps(), 0),
            f(p.perf_cost_paper(), 2),
            d.mvm_groups.to_string(),
            d.actpro_groups.to_string(),
        ]);
    }
    print!("{}", t8.render());
    let best = CATALOG
        .iter()
        .max_by(|a, b| a.perf_cost().partial_cmp(&b.perf_cost()).unwrap())
        .unwrap();
    println!("argmax F = {} (paper selects XC7S75-2) — {}", best.name,
        if best.name == "XC7S75-2" { "MATCH" } else { "MISMATCH" });
}
