//! Cross-layer golden check (E-GOLD): the simulated Matrix Machine vs
//! the AOT-compiled JAX+Pallas artifact executed through PJRT, on a full
//! SGD training step — driven through the session front door (typed
//! tensor handles + raw `step()`). Run `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example golden_check
//! ```

use mfnn::runtime::{GoldenModel, Runtime};
use mfnn::util::Rng;
use mfnn::{CompileOptions, Compiler, Session, Target};
use mfnn::hw::FpgaDevice;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = Runtime::default_dir();
    let g = GoldenModel::open(&dir)?;
    println!(
        "artifacts: dims {:?} batch {} Q{}.{} (PJRT CPU)",
        g.spec.layers.iter().map(|l| l.inputs).chain([g.spec.output_dim()]).collect::<Vec<_>>(),
        g.batch,
        16 - g.spec.fixed.frac_bits,
        g.spec.fixed.frac_bits,
    );
    let compiler = Compiler::new();
    let artifact = compiler.compile_spec(&g.spec, &CompileOptions::training(g.batch, g.lr))?;
    let mut s = Session::open(artifact.clone(), Target::Board(FpgaDevice::selected()))?;
    let fsp = g.spec.fixed;
    let mut r = Rng::new(2026);
    let mut rand = |n: usize, amp: f64| -> Vec<i16> {
        (0..n).map(|_| fsp.from_f64((r.gen_f64() - 0.5) * amp)).collect()
    };
    let mut ws: Vec<Vec<i16>> =
        g.spec.layers.iter().map(|l| rand(l.inputs * l.outputs, 1.2)).collect();
    let mut bs: Vec<Vec<i16>> = g.spec.layers.iter().map(|l| rand(l.outputs, 0.4)).collect();
    for l in 0..g.spec.layers.len() {
        s.write(&artifact.tensor(&format!("w{l}"))?, &ws[l])?;
        s.write(&artifact.tensor(&format!("b{l}"))?, &bs[l])?;
    }
    let hx = artifact.tensor("x")?;
    let hy = artifact.tensor("y")?;
    let last = g.spec.layers.len() - 1;
    let hout = artifact.tensor(&format!("o{last}"))?;
    let hloss = artifact.tensor("loss")?;
    for step in 0..5 {
        let x = rand(g.batch * g.spec.input_dim(), 2.0);
        let y = rand(g.batch * g.spec.output_dim(), 1.0);
        s.write(&hx, &x)?;
        s.write(&hy, &y)?;
        s.step();
        let gold = g.train_step(&x, &y, &ws, &bs)?;
        assert_eq!(s.read(&hout)?, gold.out, "step {step}: outputs");
        assert_eq!(s.read(&hloss)?[0], gold.loss, "step {step}: loss");
        for l in 0..g.spec.layers.len() {
            let hw = artifact.tensor(&format!("w{l}"))?;
            let hb = artifact.tensor(&format!("b{l}"))?;
            assert_eq!(s.read(&hw)?, gold.weights[l], "step {step} w{l}");
            assert_eq!(s.read(&hb)?, gold.biases[l], "step {step} b{l}");
            ws[l] = gold.weights[l].clone();
            bs[l] = gold.biases[l].clone();
        }
        println!("step {step}: outputs, loss lane, weights — bit-exact ✓");
    }
    println!("\nsimulated Matrix Machine ≡ JAX/Pallas golden model over 5 chained SGD steps");
    Ok(())
}
