//! Cross-layer golden check (E-GOLD): the simulated Matrix Machine vs
//! the AOT-compiled JAX+Pallas artifact executed through PJRT, on a full
//! SGD training step. Run `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example golden_check
//! ```

use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::nn::lowering::lower_train_step;
use mfnn::runtime::{GoldenModel, Runtime};
use mfnn::util::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = Runtime::default_dir();
    let g = GoldenModel::open(&dir)?;
    println!(
        "artifacts: dims {:?} batch {} Q{}.{} (PJRT CPU)",
        g.spec.layers.iter().map(|l| l.inputs).chain([g.spec.output_dim()]).collect::<Vec<_>>(),
        g.batch,
        16 - g.spec.fixed.frac_bits,
        g.spec.fixed.frac_bits,
    );
    let h = lower_train_step(&g.spec, g.batch, g.lr)?;
    let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program)?;
    let fsp = g.spec.fixed;
    let mut r = Rng::new(2026);
    let mut rand = |n: usize, amp: f64| -> Vec<i16> {
        (0..n).map(|_| fsp.from_f64((r.gen_f64() - 0.5) * amp)).collect()
    };
    let mut ws: Vec<Vec<i16>> =
        g.spec.layers.iter().map(|l| rand(l.inputs * l.outputs, 1.2)).collect();
    let mut bs: Vec<Vec<i16>> = g.spec.layers.iter().map(|l| rand(l.outputs, 0.4)).collect();
    for l in 0..g.spec.layers.len() {
        m.bind(&h.program, &format!("w{l}"), &ws[l])?;
        m.bind(&h.program, &format!("b{l}"), &bs[l])?;
    }
    for step in 0..5 {
        let x = rand(g.batch * g.spec.input_dim(), 2.0);
        let y = rand(g.batch * g.spec.output_dim(), 1.0);
        m.bind(&h.program, "x", &x)?;
        m.bind(&h.program, "y", &y)?;
        m.run(&h.program)?;
        let gold = g.train_step(&x, &y, &ws, &bs)?;
        let last = g.spec.layers.len() - 1;
        assert_eq!(m.read(&h.program, &format!("o{last}"))?, gold.out, "step {step}: outputs");
        assert_eq!(m.read(&h.program, "loss")?[0], gold.loss, "step {step}: loss");
        for l in 0..g.spec.layers.len() {
            assert_eq!(m.read(&h.program, &format!("w{l}"))?, gold.weights[l], "step {step} w{l}");
            assert_eq!(m.read(&h.program, &format!("b{l}"))?, gold.biases[l], "step {step} b{l}");
            ws[l] = gold.weights[l].clone();
            bs[l] = gold.biases[l].clone();
        }
        println!("step {step}: outputs, loss lane, weights — bit-exact ✓");
    }
    println!("\nsimulated Matrix Machine ≡ JAX/Pallas golden model over 5 chained SGD steps");
    Ok(())
}
